//! Kernel validation: bit-exact agreement with the op-order reference,
//! loose agreement with f64, and the utilization shapes Table II/Fig. 8
//! depend on.

use super::gemm::{GemmKernel, GemmKind};
use super::reference::{kernel_reference, reference_gemm_f64};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::util::rng::Rng;

fn all_kinds() -> [GemmKind; 5] {
    [
        GemmKind::FmaF64,
        GemmKind::FmaSimd(ScalarFmt::S),
        GemmKind::FmaSimd(ScalarFmt::H),
        GemmKind::ExSdotp(OpWidth::HtoS),
        GemmKind::ExSdotp(OpWidth::BtoH),
    ]
}

fn random_mats(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.5).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.5).collect();
    (a, b)
}

#[test]
fn all_kernels_match_bit_exact_reference_16() {
    let (m, n, k) = (16, 16, 16);
    let (a, b) = random_mats(m, n, k, 11);
    for kind in all_kinds() {
        let kern = GemmKernel::new(kind, m, n, k);
        let run = kern.run(&a, &b);
        let want = kernel_reference(&kern, &a, &b);
        assert_eq!(run.c.len(), want.len());
        for (idx, (got, exp)) in run.c.iter().zip(&want).enumerate() {
            assert!(
                got == exp || (got.is_nan() && exp.is_nan()),
                "{} C[{}/{}]: got {got}, want {exp}",
                kind.label(),
                idx / n,
                idx % n
            );
        }
    }
}

#[test]
fn all_kernels_match_bit_exact_reference_rect() {
    // Non-square: M=16, N=24, K=32 exercises all three dims distinctly.
    let (m, n, k) = (16, 24, 32);
    let (a, b) = random_mats(m, n, k, 23);
    for kind in all_kinds() {
        let kern = GemmKernel::new(kind, m, n, k);
        let run = kern.run(&a, &b);
        let want = kernel_reference(&kern, &a, &b);
        for (got, exp) in run.c.iter().zip(&want) {
            assert!(got == exp || (got.is_nan() && exp.is_nan()), "{}", kind.label());
        }
    }
}

#[test]
fn kernels_approximate_f64_gemm() {
    let (m, n, k) = (16, 16, 16);
    let (a, b) = random_mats(m, n, k, 5);
    let gold = reference_gemm_f64(&a, &b, m, n, k);
    // Expected relative accuracy scales with the source-format mantissa.
    for (kind, tol) in [
        (GemmKind::FmaF64, 1e-14),
        (GemmKind::FmaSimd(ScalarFmt::S), 1e-5),
        (GemmKind::FmaSimd(ScalarFmt::H), 2e-2),
        (GemmKind::ExSdotp(OpWidth::HtoS), 2e-2),
        (GemmKind::ExSdotp(OpWidth::BtoH), 0.3),
    ] {
        let kern = GemmKernel::new(kind, m, n, k);
        let run = kern.run(&a, &b);
        let mut worst = 0f64;
        for (got, exp) in run.c.iter().zip(&gold) {
            let denom = exp.abs().max(1.0);
            worst = worst.max((got - exp).abs() / denom);
        }
        assert!(worst < tol, "{}: worst rel err {worst} > {tol}", kind.label());
    }
}

#[test]
fn utilization_shape_matches_paper() {
    // 64×64 (K=64): FLOP/cycle per kernel must land in the paper's
    // utilization bands (Table II ±). Peaks: FP64 2/core, FP32 4, FP16 8,
    // 16→32 8, 8→16 16 → cluster ×8.
    let (a, b) = random_mats(64, 64, 64, 77);
    let check = |kind: GemmKind, peak: f64, lo_util: f64, hi_util: f64| {
        let kern = GemmKernel::new(kind, 64, 64, 64);
        let run = kern.run(&a, &b);
        let fpc = run.flop_per_cycle();
        let util = fpc / (peak * 8.0);
        assert!(
            (lo_util..hi_util).contains(&util),
            "{}: {fpc:.2} FLOP/cycle = {:.0}% of peak (expected {:.0}–{:.0}%)",
            kind.label(),
            util * 100.0,
            lo_util * 100.0,
            hi_util * 100.0
        );
    };
    check(GemmKind::FmaF64, 2.0, 0.70, 1.0);
    check(GemmKind::FmaSimd(ScalarFmt::S), 4.0, 0.60, 1.0);
    check(GemmKind::FmaSimd(ScalarFmt::H), 8.0, 0.50, 0.95);
    check(GemmKind::ExSdotp(OpWidth::HtoS), 8.0, 0.55, 0.95);
    check(GemmKind::ExSdotp(OpWidth::BtoH), 16.0, 0.40, 0.95);
}

#[test]
fn exsdotp_beats_fma_at_same_source_width() {
    // The headline claim: the 16→32 ExSdotp kernel is faster than the
    // FP16 FMA kernel at equal size (paper: up to 10% fewer cycles), and
    // the 8→16 kernel roughly doubles the FP16 FMA throughput.
    let (a, b) = random_mats(64, 64, 64, 99);
    let fma16 = GemmKernel::new(GemmKind::FmaSimd(ScalarFmt::H), 64, 64, 64).run(&a, &b);
    let ex1632 = GemmKernel::new(GemmKind::ExSdotp(OpWidth::HtoS), 64, 64, 64).run(&a, &b);
    let ex816 = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), 64, 64, 64).run(&a, &b);
    assert!(
        ex1632.cycles < fma16.cycles,
        "16→32 ExSdotp ({}) must beat FP16 FMA ({})",
        ex1632.cycles,
        fma16.cycles
    );
    let speedup = fma16.cycles as f64 / ex816.cycles as f64;
    assert!(
        (1.3..2.2).contains(&speedup),
        "8→16 vs FP16 FMA speedup {speedup:.2} out of the paper's 1.56–2× band"
    );
}

#[test]
fn functional_mode_matches_simulation_at_scale() {
    // Larger than the batch module's unit tests: one 32×32 (K=64)
    // FP8→FP16 problem through the full simulator vs the batch engine.
    let (m, n, k) = (32, 32, 64);
    let (a, b) = random_mats(m, n, k, 5);
    let kern = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k);
    let sim = kern.run_mode(&a, &b, super::gemm::ExecMode::CycleAccurate);
    let fun = kern.run_mode(&a, &b, super::gemm::ExecMode::Functional);
    assert_eq!(sim.c, fun.c, "Functional C must be bit-identical to the simulated C");
}

#[test]
fn model_cycles_tracks_simulation() {
    // The Functional-mode issue-slot model must land near the simulated
    // cycle counts on the paper-anchored 64×64 kernels. It ignores bank
    // conflicts and RAW stalls by design, so the band is generous.
    let (a, b) = random_mats(64, 64, 64, 77);
    for kind in [
        GemmKind::FmaSimd(ScalarFmt::H),
        GemmKind::ExSdotp(OpWidth::HtoS),
        GemmKind::ExSdotp(OpWidth::BtoH),
    ] {
        let kern = GemmKernel::new(kind, 64, 64, 64);
        let sim = kern.run(&a, &b).cycles as f64;
        let model = kern.model_cycles() as f64;
        let ratio = model / sim;
        assert!(
            (0.65..1.35).contains(&ratio),
            "{}: model {model} vs simulated {sim} (ratio {ratio:.2})",
            kind.label()
        );
    }
}

#[test]
fn footprint_matches_table2_feasibility() {
    // The paper: FP8→16 fits 128×256; FP16-only fits 128×128; FP64 only
    // 64×64 (within 128 kB).
    let fits = |kind: GemmKind, m: usize, n: usize| GemmKernel::new(kind, m, n, m).footprint() <= 128 * 1024;
    assert!(fits(GemmKind::FmaF64, 64, 64));
    assert!(!fits(GemmKind::FmaF64, 64, 128));
    assert!(fits(GemmKind::FmaSimd(ScalarFmt::H), 128, 128));
    assert!(!fits(GemmKind::FmaSimd(ScalarFmt::H), 128, 256));
    assert!(fits(GemmKind::ExSdotp(OpWidth::BtoH), 128, 256));
    assert!(fits(GemmKind::ExSdotp(OpWidth::HtoS), 128, 128));
}

#[test]
fn kernel_program_is_compact_and_disassembles() {
    let kern = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), 64, 64, 64);
    let prog = kern.program(0);
    // A real kernel, not an unrolled monster: FREP keeps it small.
    assert!(prog.len() < 120, "program has {} instructions", prog.len());
    let text = crate::isa::asm::disassemble_program(&prog);
    assert!(text.contains("exsdotp.h.b"));
    assert!(text.contains("frep.o"));
    assert!(text.contains("scfgwi"));
    // Every line reassembles.
    for line in text.lines() {
        let body = line.splitn(2, ':').nth(1).unwrap().trim();
        assert!(crate::isa::asm::assemble_line(body).is_some(), "line '{body}'");
    }
}
