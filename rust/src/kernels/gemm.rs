//! The GEMM kernel generators and their run harness.

use super::layout::{pack_matrix, pack_matrix_ld, unpack_matrix, MatrixOrder};
use crate::cluster::{Cluster, ClusterCfg, TCDM_BASE};
use crate::core::CoreStats;
use crate::formats::FpFormat;
use crate::isa::csr::addr as csr;
use crate::isa::instr::regs::*;
use crate::isa::instr::{Instr, OpWidth, Reg, ScalarFmt};
use crate::softfloat::RoundingMode;
use crate::util::error::{Error, Result};

/// How to execute a bound GEMM problem.
///
/// The two modes produce **bit-identical C matrices** (asserted by the
/// differential tests): they run the same numerics in the same
/// accumulation order. They differ in what else you get and what it
/// costs:
///
/// * [`ExecMode::CycleAccurate`] — simulate the 8-core cluster cycle by
///   cycle: exact cycle counts, stall breakdowns, bank-conflict
///   behaviour. The mode behind Table II / Fig. 8. Cost: every lane of
///   every instruction wades through the full machine model.
/// * [`ExecMode::Functional`] — run the batch engine
///   (`batch::gemm_dispatch`): packed registers, monomorphized
///   kernels, rows in parallel. Orders of magnitude faster; cycles come
///   from the analytic issue-slot model ([`GemmKernel::model_cycles`])
///   instead of simulation, and per-instruction stats are not
///   collected. The mode for accuracy sweeps and large-scale runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ExecMode {
    /// Cycle-by-cycle cluster simulation (exact timing, slow).
    CycleAccurate,
    /// Batch-engine execution (bit-identical C, modeled timing, fast).
    Functional,
}

/// Which Table II kernel family.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GemmKind {
    /// Scalar FP64 FMA kernel (8-column unroll) — the classic Snitch GEMM.
    FmaF64,
    /// Packed-SIMD FMA kernel (`.s` = 2×FP32 or `.h` = 4×FP16 lanes).
    FmaSimd(ScalarFmt),
    /// Expanding sum-of-dot-product kernel (16→32 or 8→16).
    ExSdotp(OpWidth),
}

impl GemmKind {
    /// Source element format (inputs A, B), validated: `FmaSimd` only
    /// has `.s` (2×FP32) and `.h` (4×FP16) kernel variants — other
    /// [`ScalarFmt`]s return a typed error instead of panicking. This is
    /// the check the plan builder ([`crate::api::Session::gemm`])
    /// surfaces at plan-build time.
    pub fn try_src_fmt(&self) -> Result<FpFormat> {
        match self {
            GemmKind::FmaF64 => Ok(crate::formats::FP64),
            GemmKind::FmaSimd(ScalarFmt::S) => Ok(crate::formats::FP32),
            GemmKind::FmaSimd(ScalarFmt::H) => Ok(crate::formats::FP16),
            GemmKind::FmaSimd(f) => Err(Error::msg(format!(
                "unsupported SIMD FMA format {f:?}: packed-FMA GEMM kernels exist \
                 for .s (2xFP32) and .h (4xFP16) only (use GemmKind::FmaF64 for FP64)"
            ))),
            GemmKind::ExSdotp(OpWidth::HtoS) => Ok(crate::formats::FP16),
            GemmKind::ExSdotp(OpWidth::BtoH) => Ok(crate::formats::FP8),
        }
    }

    /// Output element format (C), validated like [`GemmKind::try_src_fmt`].
    pub fn try_dst_fmt(&self) -> Result<FpFormat> {
        match self {
            GemmKind::ExSdotp(OpWidth::HtoS) => Ok(crate::formats::FP32),
            GemmKind::ExSdotp(OpWidth::BtoH) => Ok(crate::formats::FP16),
            _ => self.try_src_fmt(),
        }
    }

    /// Check that this kind names a kernel the hardware (and this crate)
    /// actually implements.
    pub fn validate(&self) -> Result<()> {
        self.try_src_fmt().map(|_| ())
    }

    /// Resolve a `(source, accumulation)` format pair to its Table II
    /// kernel family — the typed front door the plan builder uses.
    /// Unsupported pairs are a typed error, not a panic.
    pub fn for_formats(src: FpFormat, dst: FpFormat) -> Result<GemmKind> {
        use crate::formats::{FP16, FP32, FP64, FP8};
        match (src, dst) {
            (s, d) if s == FP64 && d == FP64 => Ok(GemmKind::FmaF64),
            (s, d) if s == FP32 && d == FP32 => Ok(GemmKind::FmaSimd(ScalarFmt::S)),
            (s, d) if s == FP16 && d == FP16 => Ok(GemmKind::FmaSimd(ScalarFmt::H)),
            (s, d) if s == FP16 && d == FP32 => Ok(GemmKind::ExSdotp(OpWidth::HtoS)),
            (s, d) if s == FP8 && d == FP16 => Ok(GemmKind::ExSdotp(OpWidth::BtoH)),
            _ => Err(Error::msg(format!(
                "no GEMM kernel for {}->{}: supported pairs are FP64->FP64 (FMA), \
                 FP32->FP32 (SIMD FMA), FP16->FP16 (SIMD FMA), FP16->FP32 (ExSdotp), \
                 FP8->FP16 (ExSdotp)",
                src.name(),
                dst.name()
            ))),
        }
    }

    /// Source element format (inputs A, B).
    ///
    /// Panics for kinds [`GemmKind::validate`] rejects; the typed API
    /// validates before ever reaching this (prefer [`GemmKind::try_src_fmt`]).
    pub fn src_fmt(&self) -> FpFormat {
        match self.try_src_fmt() {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        }
    }

    /// Output element format (C). Panics like [`GemmKind::src_fmt`];
    /// prefer [`GemmKind::try_dst_fmt`].
    pub fn dst_fmt(&self) -> FpFormat {
        match self.try_dst_fmt() {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        }
    }

    /// Source lanes per 64-bit word.
    pub fn lanes(&self) -> usize {
        (64 / self.src_fmt().width()) as usize
    }

    /// Output-column unroll factor (accumulators in flight).
    pub fn unroll(&self) -> usize {
        match self {
            GemmKind::FmaF64 => 8,
            _ => 4,
        }
    }

    /// Short label (Table II column).
    pub fn label(&self) -> &'static str {
        match self {
            GemmKind::FmaF64 => "FP64 FMA",
            GemmKind::FmaSimd(ScalarFmt::S) => "FP32 FMA",
            GemmKind::FmaSimd(_) => "FP16 FMA",
            GemmKind::ExSdotp(OpWidth::HtoS) => "FP16->FP32 ExSdotp",
            GemmKind::ExSdotp(OpWidth::BtoH) => "FP8->FP16 ExSdotp",
        }
    }

    /// B matrix storage order this kernel streams.
    pub fn b_order(&self) -> MatrixOrder {
        match self {
            GemmKind::FmaF64 => MatrixOrder::RowMajor,
            _ => MatrixOrder::ColMajor,
        }
    }
}

/// A sized GEMM problem bound to a kernel kind.
#[derive(Clone, Copy, Debug)]
pub struct GemmKernel {
    /// Kernel family.
    pub kind: GemmKind,
    /// Output rows.
    pub m: usize,
    /// Output columns.
    pub n: usize,
    /// Inner dimension.
    pub k: usize,
    /// Compute cores.
    pub n_cores: usize,
}

/// Result of a simulated GEMM run.
pub struct GemmResult {
    /// Total cluster cycles.
    pub cycles: u64,
    /// C matrix decoded to f64 (row-major).
    pub c: Vec<f64>,
    /// FLOP performed (2·M·N·K).
    pub flops: u64,
    /// Aggregate core stats.
    pub stats: CoreStats,
}

impl GemmResult {
    /// FLOP per cycle across the cluster (Fig. 8's y-axis).
    pub fn flop_per_cycle(&self) -> f64 {
        self.flops as f64 / self.cycles as f64
    }
}

impl GemmKernel {
    /// Bind a problem, validating the kernel kind and the divisibility
    /// requirements (`M % cores == 0`, `N % unroll == 0`,
    /// `K % lanes == 0`) as typed errors. The front door for the plan
    /// builder ([`crate::api::GemmPlanBuilder::dims`]).
    pub fn try_new(kind: GemmKind, m: usize, n: usize, k: usize) -> Result<Self> {
        kind.validate()?;
        let n_cores = 8;
        crate::ensure!(
            m > 0 && m % n_cores == 0,
            "M ({m}) must be a positive multiple of {n_cores} (compute cores)"
        );
        crate::ensure!(
            n > 0 && n % kind.unroll() == 0,
            "N ({n}) must be a positive multiple of the kernel's unroll factor ({})",
            kind.unroll()
        );
        crate::ensure!(
            k > 0 && k % kind.lanes() == 0,
            "K ({k}) must be a positive multiple of the kernel's SIMD width ({})",
            kind.lanes()
        );
        Ok(GemmKernel { kind, m, n, k, n_cores })
    }

    /// Bind a problem. Panics on sizes [`GemmKernel::try_new`] rejects —
    /// kept as the pre-plan-API shim; prefer `try_new` (or the typed
    /// plan builder) in new code.
    pub fn new(kind: GemmKind, m: usize, n: usize, k: usize) -> Self {
        match Self::try_new(kind, m, n, k) {
            Ok(kern) => kern,
            Err(e) => panic!("{e}"),
        }
    }

    /// Paper-style size label (`M×N`, with K = M implied in Table II).
    pub fn size_label(&self) -> String {
        format!("{}x{}", self.m, self.n)
    }

    /// Total FLOP (1 MAC = 2 FLOP; 1 ExSdotp = 4 FLOP — same count).
    pub fn flops(&self) -> u64 {
        2 * (self.m * self.n * self.k) as u64
    }

    // ------------------------------------------------------ memory layout

    fn src_bytes(&self) -> usize {
        self.kind.src_fmt().width() as usize / 8
    }

    fn dst_bytes(&self) -> usize {
        self.kind.dst_fmt().width() as usize / 8
    }

    /// TCDM base address of A (row-major, src fmt).
    pub fn a_base(&self) -> u64 {
        TCDM_BASE
    }

    /// TCDM base address of B (order per kernel, src fmt).
    pub fn b_base(&self) -> u64 {
        align64(self.a_base() + (self.m * self.k * self.src_bytes()) as u64)
    }

    /// B leading dimension in elements: the logical extent plus one
    /// 64-bit padding word whenever a major line would otherwise be a
    /// multiple of the bank-group size (lines aliasing onto one bank
    /// group serialize the whole cluster — the kernels pad, like the
    /// hand-written Snitch GEMMs).
    pub fn b_ld(&self) -> usize {
        let (extent, sw) = match self.kind.b_order() {
            MatrixOrder::RowMajor => (self.n, self.src_bytes()),
            MatrixOrder::ColMajor => (self.k, self.src_bytes()),
        };
        if (extent * sw) % 64 == 0 {
            extent + 8 / sw
        } else {
            extent
        }
    }

    fn b_bytes_padded(&self) -> usize {
        let lines = match self.kind.b_order() {
            MatrixOrder::RowMajor => self.k,
            MatrixOrder::ColMajor => self.n,
        };
        lines * self.b_ld() * self.src_bytes()
    }

    /// TCDM base address of C (row-major, dst fmt).
    pub fn c_base(&self) -> u64 {
        align64(self.b_base() + self.b_bytes_padded() as u64)
    }

    /// Logical TCDM footprint in bytes — the paper's "fits in the 128 kB
    /// local memory" criterion counts data, not anti-aliasing padding.
    pub fn footprint(&self) -> u64 {
        ((self.m * self.k + self.k * self.n) * self.src_bytes() + self.m * self.n * self.dst_bytes()) as u64
    }

    /// Physical footprint including padding and alignment.
    pub fn footprint_padded(&self) -> u64 {
        self.c_base() + (self.m * self.n * self.dst_bytes()) as u64 - TCDM_BASE
    }

    // ------------------------------------------------------ program

    /// Generate the per-core program.
    pub fn program(&self, core_id: u32) -> Vec<Instr> {
        let mut p = Vec::with_capacity(128);
        let u = self.kind.unroll();
        let l = self.kind.lanes();
        let sw = self.src_bytes();
        let dw = self.dst_bytes();
        let (m, n, k) = (self.m, self.n, self.k);
        let rows = m / self.n_cores;
        let blocks = n / u;
        let kc = k / l; // inner-loop iterations (words per A-row sweep)

        // ---- SSR configuration (once per run) -------------------------
        // ft0 streams A: [kc words] × [blocks (restart)] × [rows].
        let a_row0 = self.a_base() + (core_id as usize * k * sw) as u64;
        let a_row_stride = (self.n_cores * k * sw) as i64;
        scfg(&mut p, 0, crate::core::cfg_regs::BOUND0, kc as i64);
        scfg(&mut p, 0, crate::core::cfg_regs::BOUND0 + 1, blocks as i64);
        scfg(&mut p, 0, crate::core::cfg_regs::BOUND0 + 2, rows as i64);
        scfg(&mut p, 0, crate::core::cfg_regs::STRIDE0, 8);
        scfg(&mut p, 0, crate::core::cfg_regs::STRIDE0 + 1, 0);
        scfg(&mut p, 0, crate::core::cfg_regs::STRIDE0 + 2, a_row_stride);
        scfg(&mut p, 0, crate::core::cfg_regs::REPEAT, u as i64);
        scfg(&mut p, 0, crate::core::cfg_regs::RPTR0 + 2, a_row0 as i64);

        // ft1 streams B.
        match self.kind {
            GemmKind::FmaF64 => {
                // Row-major B: [8 cols] × [k rows] × [blocks] × [rows(0)].
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0, u as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 1, k as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 2, blocks as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 3, rows as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0, 8);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 1, (self.b_ld() * 8) as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 2, (u * 8) as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 3, 0);
                scfg(&mut p, 1, crate::core::cfg_regs::RPTR0 + 3, self.b_base() as i64);
            }
            _ => {
                // Column-major B: [u cols] × [kc words] × [blocks] × [rows(0)].
                let col_bytes = (self.b_ld() * sw) as i64;
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0, u as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 1, kc as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 2, blocks as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::BOUND0 + 3, rows as i64);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0, col_bytes);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 1, 8);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 2, u as i64 * col_bytes);
                scfg(&mut p, 1, crate::core::cfg_regs::STRIDE0 + 3, 0);
                scfg(&mut p, 1, crate::core::cfg_regs::RPTR0 + 3, self.b_base() as i64);
            }
        }

        // ---- scalar setup ---------------------------------------------
        p.push(Instr::FmvWX { fd: f(31), rs1: ZERO }); // f31 = +0.0 (zeroing source)
        p.push(Instr::Csrrwi { rd: ZERO, csr: csr::SSR, imm: 1 });
        li(&mut p, x(6), kc as i64 - 1); // FREP repetition count (body runs kc times)
        li(&mut p, x(20), rows as i64); // row loop counter
        // C pointer for this core's first row.
        li(&mut p, x(22), (self.c_base() + (core_id as usize * n * dw) as u64) as i64);
        // Row skip: advance from end of row i to start of row i+n_cores.
        li(&mut p, x(24), ((self.n_cores - 1) * n * dw) as i64);

        // ---- row loop ----------------------------------------------------
        let row_loop_start = p.len() as i32;
        li(&mut p, x(21), blocks as i64); // block loop counter

        // ---- block loop ---------------------------------------------------
        let block_loop_start = p.len() as i32;
        // Zero the accumulators (FP-side, stays ordered in the FP queue).
        for a in 0..u {
            p.push(Instr::Fsgnj { fmt: ScalarFmt::D, fd: f(4 + a as u8), fs1: f(31), fs2: f(31) });
        }
        // The hot loop: one FREP over `u` independent compute ops.
        p.push(Instr::FrepO { rep: x(6), n_inst: u as u8 });
        for a in 0..u {
            let acc = f(4 + a as u8);
            match self.kind {
                GemmKind::FmaF64 => {
                    p.push(Instr::Fmadd { fmt: ScalarFmt::D, fd: acc, fs1: FT0, fs2: FT1, fs3: acc })
                }
                GemmKind::FmaSimd(fmt) => {
                    p.push(Instr::Fmadd { fmt, fd: acc, fs1: FT0, fs2: FT1, fs3: acc })
                }
                GemmKind::ExSdotp(w) => p.push(Instr::ExSdotp { w, fd: acc, fs1: FT0, fs2: FT1 }),
            }
        }
        // Epilogue: reduce lanes and store C.
        match self.kind {
            GemmKind::FmaF64 => {
                for a in 0..u {
                    p.push(Instr::FStore {
                        fmt: ScalarFmt::D,
                        rs1: x(22),
                        fs: f(4 + a as u8),
                        imm: (a * 8) as i32,
                    });
                }
            }
            GemmKind::FmaSimd(ScalarFmt::S) | GemmKind::ExSdotp(OpWidth::HtoS) => {
                // 2 FP32 lanes → 1 value: zero t, vsum, store word. The
                // phases are interleaved across the 4 columns so the
                // 3-cycle vsum latency hides behind independent work.
                for a in 0..u {
                    p.push(Instr::Fsgnj { fmt: ScalarFmt::D, fd: f(20 + a as u8), fs1: f(31), fs2: f(31) });
                }
                for a in 0..u {
                    p.push(Instr::Vsum { w: OpWidth::HtoS, fd: f(20 + a as u8), fs1: f(4 + a as u8) });
                }
                for a in 0..u {
                    p.push(Instr::FStore {
                        fmt: ScalarFmt::S,
                        rs1: x(22),
                        fs: f(20 + a as u8),
                        imm: (a * dw) as i32,
                    });
                }
            }
            GemmKind::FmaSimd(_) | GemmKind::ExSdotp(OpWidth::BtoH) => {
                // 4 FP16 lanes → 1 value: two vsum levels, phase-ordered
                // across columns for the same latency-hiding reason.
                for a in 0..u {
                    p.push(Instr::Fsgnj { fmt: ScalarFmt::D, fd: f(20 + a as u8), fs1: f(31), fs2: f(31) });
                }
                for a in 0..u {
                    p.push(Instr::Vsum { w: OpWidth::BtoH, fd: f(20 + a as u8), fs1: f(4 + a as u8) });
                }
                for a in 0..u {
                    p.push(Instr::Fsgnj { fmt: ScalarFmt::D, fd: f(25 + a as u8), fs1: f(31), fs2: f(31) });
                }
                for a in 0..u {
                    p.push(Instr::Vsum { w: OpWidth::BtoH, fd: f(25 + a as u8), fs1: f(20 + a as u8) });
                }
                for a in 0..u {
                    p.push(Instr::FStore {
                        fmt: ScalarFmt::H,
                        rs1: x(22),
                        fs: f(25 + a as u8),
                        imm: (a * dw) as i32,
                    });
                }
            }
        }
        // Advance C pointer to the next block; loop.
        p.push(Instr::Addi { rd: x(22), rs1: x(22), imm: (u * dw) as i32 });
        p.push(Instr::Addi { rd: x(21), rs1: x(21), imm: -1 });
        let off = block_loop_start - p.len() as i32;
        p.push(Instr::Bne { rs1: x(21), rs2: ZERO, offset: off });

        // Next row (skip the rows owned by other cores).
        p.push(Instr::Add { rd: x(22), rs1: x(22), rs2: x(24) });
        p.push(Instr::Addi { rd: x(20), rs1: x(20), imm: -1 });
        let off = row_loop_start - p.len() as i32;
        p.push(Instr::Bne { rs1: x(20), rs2: ZERO, offset: off });

        p.push(Instr::Halt);
        p
    }

    // ------------------------------------------------------ harness

    /// Execute in the given [`ExecMode`]. Both modes return the same C
    /// bits; see the mode docs for the timing/stats trade-off.
    pub fn run_mode(&self, a: &[f64], b: &[f64], mode: ExecMode) -> GemmResult {
        match mode {
            ExecMode::CycleAccurate => self.run(a, b),
            ExecMode::Functional => {
                let c = crate::batch::gemm_dispatch(self.kind, self.m, self.n, self.k, a, b, RoundingMode::Rne);
                GemmResult {
                    cycles: self.model_cycles(),
                    c,
                    flops: self.flops(),
                    stats: CoreStats::default(),
                }
            }
        }
    }

    /// Analytic cycle model for [`ExecMode::Functional`]: counts the FP
    /// issue slots of the generated program, which bound runtime on the
    /// pseudo-dual-issue PE (one FP issue per cycle; integer loop
    /// control runs in the shadow of FP compute).
    ///
    /// Per core: each of `rows × blocks` accumulator blocks zeroes `U`
    /// accumulators, issues `U·kc` compute ops under FREP, and runs the
    /// kernel's epilogue (`vsum` tree + stores); small per-block,
    /// per-row and startup overheads cover the non-hidden scalar work.
    /// An *issue-slot* estimate, deliberately blind to bank conflicts
    /// and RAW stalls — designed to land within ~±15% of the simulator
    /// on the Table II grid (the `model_cycles_tracks_simulation` test
    /// keeps it honest with a generous band).
    pub fn model_cycles(&self) -> u64 {
        let u = self.kind.unroll() as u64;
        let kc = (self.k / self.kind.lanes()) as u64;
        let rows = (self.m / self.n_cores) as u64;
        let blocks = (self.n / self.kind.unroll()) as u64;
        // Epilogue FP issues per block, by kernel family (see program()).
        let epilogue = match self.kind {
            GemmKind::FmaF64 => u,                                     // stores
            GemmKind::FmaSimd(ScalarFmt::S) => 3 * u,                  // zero+vsum+store
            GemmKind::ExSdotp(OpWidth::HtoS) => 3 * u,                 // zero+vsum+store
            _ => 5 * u,                                                // two vsum levels
        };
        let per_block = u + u * kc + epilogue + 2; // +2: C-pointer bump, branch shadow
        let per_row = blocks * per_block + 5;
        let startup = 40; // SSR configuration + scalar setup
        rows * per_row + startup
    }

    /// Pack inputs, run on a simulated cluster, decode C.
    /// `a` is M×K and `b` is K×N, both row-major f64 (quantized to the
    /// source format on packing).
    pub fn run(&self, a: &[f64], b: &[f64]) -> GemmResult {
        let src = self.kind.src_fmt();
        let dst = self.kind.dst_fmt();
        let a_pack = pack_matrix(a, self.m, self.k, src, MatrixOrder::RowMajor);
        let b_pack = pack_matrix_ld(b, self.k, self.n, src, self.kind.b_order(), self.b_ld());

        // The simulated TCDM gets a little headroom over the paper's
        // 128 kB so the two largest problems still fit WITH the
        // anti-aliasing padding; feasibility (Table II) is checked on
        // the logical footprint.
        let cfg = ClusterCfg {
            n_cores: self.n_cores as u32,
            tcdm_size: (136 * 1024).max((self.footprint_padded() as u32 + 4095) & !4095),
            // GEMM kernels never touch global memory; don't allocate
            // (and memset) the default 16 MiB per run.
            global_size: 4096,
            ..ClusterCfg::default()
        };
        assert!(
            self.footprint() <= 128 * 1024,
            "GEMM {} does not fit the paper's 128 kB TCDM",
            self.size_label(),
        );
        let mut cl = Cluster::new(cfg, |id| self.program(id));
        cl.store_bytes(self.a_base(), &a_pack);
        cl.store_bytes(self.b_base(), &b_pack);

        let cycles = cl.run(200_000_000);
        let c_bytes = cl.load_bytes(self.c_base(), self.m * self.n * self.dst_bytes());
        let c = unpack_matrix(&c_bytes, self.m, self.n, dst, MatrixOrder::RowMajor);
        GemmResult { cycles, c, flops: self.flops(), stats: cl.stats() }
    }
}

fn align64(a: u64) -> u64 {
    (a + 63) & !63
}

/// Emit an SSR config write: `li x5, value; scfgwi x5, streamer*32+reg`.
fn scfg(p: &mut Vec<Instr>, streamer: u16, reg: u16, value: i64) {
    li(p, x(5), value);
    p.push(Instr::ScfgWi { rs1: x(5), cfg: streamer * 32 + reg });
}

/// Emit `li rd, value` (addi, or lui+addi).
pub(crate) fn li(p: &mut Vec<Instr>, rd: Reg, value: i64) {
    let v = value as i32;
    if (-2048..2048).contains(&v) {
        p.push(Instr::Addi { rd, rs1: ZERO, imm: v });
    } else {
        let hi = (v + 0x800) >> 12;
        let lo = v - (hi << 12);
        p.push(Instr::Lui { rd, imm: hi });
        if lo != 0 {
            p.push(Instr::Addi { rd, rs1: rd, imm: lo });
        }
    }
}
