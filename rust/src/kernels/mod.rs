//! GEMM kernel generators (§IV-B).
//!
//! The paper implements "a collection of FMA- and ExSdotp-based GEMM
//! kernels for different formats and problem sizes ... compiled with an
//! extended LLVM-12 using intrinsics", all built on SSR + FREP. We
//! reproduce the same kernel *structure* as instruction-sequence
//! generators:
//!
//! * every core owns the output rows `i ≡ core_id (mod 8)`;
//! * the SSRs are configured **once** per core with 3-D/4-D affine
//!   patterns covering the whole row sweep (`A` via `ft0` with element
//!   repetition, `B` via `ft1`);
//! * the inner loop is a single `frep` over `U` independent
//!   accumulators (one per unrolled output column), so the 3-cycle FPU
//!   latency is hidden without any branch or load instruction;
//! * the epilogue reduces packed accumulator lanes with `vsum` and
//!   stores `C` — the part whose cost the expanding ExSdotp kernels
//!   halve relative to non-expanding SIMD FMA kernels (§IV-B's ~10%).
//!
//! `C = A·B` with `A: M×K` row-major, `B: K×N` column-major for packed
//! kernels (row-major for the scalar FP64 kernel), `C: M×N` row-major.
//! GEMM sizes are labeled `M×N` with `K = M`, matching Table II (the
//! memory-footprint arithmetic only works out under this reading).

pub mod gemm;
pub mod layout;
pub mod reference;
#[cfg(test)]
mod tests;

pub use gemm::{ExecMode, GemmKernel, GemmKind};
pub use layout::{pack_matrix, unpack_matrix, MatrixOrder};
pub use reference::{kernel_reference, reference_gemm_f64};
