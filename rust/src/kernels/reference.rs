//! Bit-exact references for the GEMM kernels.
//!
//! [`kernel_reference`] replays the *identical* accumulation order and
//! arithmetic units the generated kernel uses (per-lane partial sums,
//! vsum reduction tree, single rounding per ExSdotp), so a simulated run
//! must match it **bit for bit** — this pins down the SSR address
//! patterns and the whole data-movement pipeline, independent of FP
//! error tolerances. [`reference_gemm_f64`] is the loose oracle: plain
//! f64 GEMM for relative-error sanity bounds.

use super::gemm::{GemmKind, GemmKernel};
use super::layout::quantize_f64;
use crate::exsdotp::simd::{lane, set_lane, SimdExSdotp};
use crate::formats::FP64;
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::softfloat::{self, from_f64, to_f64, RoundingMode};

/// Plain f64 GEMM (C = A·B), row-major.
pub fn reference_gemm_f64(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Bit-exact replay of the kernel's accumulation order. Inputs are the
/// same f64 matrices handed to [`GemmKernel::run`]; output is C decoded
/// to f64, which must equal the simulated C exactly.
pub fn kernel_reference(kern: &GemmKernel, a: &[f64], b: &[f64]) -> Vec<f64> {
    let src = kern.kind.src_fmt();
    let (m, n, k) = (kern.m, kern.n, kern.k);
    let aq = quantize_f64(a, src);
    let bq = quantize_f64(b, src);
    let rm = RoundingMode::Rne;
    let mut c = vec![0f64; m * n];

    match kern.kind {
        GemmKind::FmaF64 => {
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0u64; // +0.0
                    for kk in 0..k {
                        let av = aq[i * k + kk].to_bits();
                        let bv = bq[kk * n + j].to_bits();
                        acc = softfloat::fma(FP64, av, bv, acc, rm);
                    }
                    c[i * n + j] = f64::from_bits(acc);
                }
            }
        }
        GemmKind::FmaSimd(fmt) => {
            // Lane-parallel partial sums over k, then the vsum tree.
            let l = kern.kind.lanes();
            let f = src;
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0u64; // packed lanes, all +0.0
                    for kc in 0..k / l {
                        let mut aw = 0u64;
                        let mut bw = 0u64;
                        for lane_i in 0..l {
                            let kk = kc * l + lane_i;
                            aw |= from_f64(aq[i * k + kk], f, rm) << (lane_i as u32 * f.width());
                            bw |= from_f64(bq[kk * n + j], f, rm) << (lane_i as u32 * f.width());
                        }
                        acc = lanewise_fma(f, aw, bw, acc, rm);
                    }
                    c[i * n + j] = vsum_reduce(kern.kind, acc, rm);
                    let _ = fmt;
                }
            }
        }
        GemmKind::ExSdotp(w) => {
            let l = kern.kind.lanes();
            let simd = SimdExSdotp::new(src, kern.kind.dst_fmt());
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0u64;
                    for kc in 0..k / l {
                        let mut aw = 0u64;
                        let mut bw = 0u64;
                        for lane_i in 0..l {
                            let kk = kc * l + lane_i;
                            aw |= from_f64(aq[i * k + kk], src, rm) << (lane_i as u32 * src.width());
                            bw |= from_f64(bq[kk * n + j], src, rm) << (lane_i as u32 * src.width());
                        }
                        acc = simd.exsdotp(aw, bw, acc, rm);
                    }
                    c[i * n + j] = vsum_reduce(kern.kind, acc, rm);
                    let _ = w;
                }
            }
        }
    }
    c
}

/// Lanewise FMA over packed words (mirrors the PE's vectorial FMA).
fn lanewise_fma(f: crate::formats::FpFormat, a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
    let w = f.width();
    let mut out = 0u64;
    for i in 0..f.lanes_in_64() {
        out = set_lane(out, i, w, softfloat::fma(f, lane(a, i, w), lane(b, i, w), lane(c, i, w), rm));
    }
    out
}

/// The kernel's epilogue reduction: fold packed accumulator lanes with
/// the same `vsum` tree the generated code uses; decode lane 0.
fn vsum_reduce(kind: GemmKind, acc: u64, rm: RoundingMode) -> f64 {
    match kind {
        GemmKind::FmaF64 => f64::from_bits(acc),
        GemmKind::FmaSimd(ScalarFmt::S) | GemmKind::ExSdotp(OpWidth::HtoS) => {
            let unit = SimdExSdotp::new(crate::formats::FP16, crate::formats::FP32);
            let t = unit.vsum(acc, 0, rm);
            to_f64(lane(t, 0, 32), crate::formats::FP32)
        }
        GemmKind::FmaSimd(_) | GemmKind::ExSdotp(OpWidth::BtoH) => {
            let unit = SimdExSdotp::new(crate::formats::FP8, crate::formats::FP16);
            let t = unit.vsum(acc, 0, rm);
            let t2 = unit.vsum(t, 0, rm);
            to_f64(lane(t2, 0, 16), crate::formats::FP16)
        }
    }
}
