//! Host-side matrix packing: `f64` matrices quantized into minifloat
//! encodings and laid out in TCDM the way the kernels stream them.

use crate::formats::FpFormat;
use crate::softfloat::{from_f64, to_f64, RoundingMode};

/// Storage order for packing.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MatrixOrder {
    /// Row-major (`data[r][c]` at `r*cols + c`).
    RowMajor,
    /// Column-major (`data[r][c]` at `c*rows + r`).
    ColMajor,
}

/// Quantize `data` (rows×cols, row-major f64) into `fmt` encodings
/// packed in the given order. Returns raw bytes (little-endian lanes).
pub fn pack_matrix(data: &[f64], rows: usize, cols: usize, fmt: FpFormat, order: MatrixOrder) -> Vec<u8> {
    let ld = match order {
        MatrixOrder::RowMajor => cols,
        MatrixOrder::ColMajor => rows,
    };
    pack_matrix_ld(data, rows, cols, fmt, order, ld)
}

/// [`pack_matrix`] with an explicit leading dimension `ld` (elements
/// per stored major line, ≥ the logical extent). Padding elements are
/// zero — GEMM kernels pad the leading dimension so that major lines do
/// not alias onto the same TCDM bank group (§IV-B kernels do the same).
pub fn pack_matrix_ld(
    data: &[f64],
    rows: usize,
    cols: usize,
    fmt: FpFormat,
    order: MatrixOrder,
    ld: usize,
) -> Vec<u8> {
    assert_eq!(data.len(), rows * cols);
    let w = fmt.width() as usize / 8;
    let lines = match order {
        MatrixOrder::RowMajor => {
            assert!(ld >= cols);
            rows
        }
        MatrixOrder::ColMajor => {
            assert!(ld >= rows);
            cols
        }
    };
    let mut out = vec![0u8; lines * ld * w];
    for r in 0..rows {
        for c in 0..cols {
            let bits = from_f64(data[r * cols + c], fmt, RoundingMode::Rne);
            let idx = match order {
                MatrixOrder::RowMajor => r * ld + c,
                MatrixOrder::ColMajor => c * ld + r,
            };
            out[idx * w..(idx + 1) * w].copy_from_slice(&bits.to_le_bytes()[..w]);
        }
    }
    out
}

/// Decode a packed matrix back to f64 (row-major output).
pub fn unpack_matrix(bytes: &[u8], rows: usize, cols: usize, fmt: FpFormat, order: MatrixOrder) -> Vec<f64> {
    let w = fmt.width() as usize / 8;
    assert!(bytes.len() >= rows * cols * w);
    let mut out = vec![0f64; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let idx = match order {
                MatrixOrder::RowMajor => r * cols + c,
                MatrixOrder::ColMajor => c * rows + r,
            };
            let mut buf = [0u8; 8];
            buf[..w].copy_from_slice(&bytes[idx * w..(idx + 1) * w]);
            out[r * cols + c] = to_f64(u64::from_le_bytes(buf), fmt);
        }
    }
    out
}

/// Quantize a host matrix to the grid of `fmt` (RNE), staying in f64 —
/// what the kernel actually computes on after packing.
pub fn quantize_f64(data: &[f64], fmt: FpFormat) -> Vec<f64> {
    data.iter().map(|&x| to_f64(from_f64(x, fmt, RoundingMode::Rne), fmt)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP64, FP8};

    #[test]
    fn pack_unpack_roundtrip_row_major() {
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pack_matrix(&data, 2, 3, FP16, MatrixOrder::RowMajor);
        assert_eq!(p.len(), 12);
        assert_eq!(unpack_matrix(&p, 2, 3, FP16, MatrixOrder::RowMajor), data);
    }

    #[test]
    fn col_major_transposes_layout() {
        let data = vec![1.0, 2.0, 3.0, 4.0];
        let p = pack_matrix(&data, 2, 2, FP64, MatrixOrder::ColMajor);
        // Column-major order: a00, a10, a01, a11.
        let vals: Vec<f64> =
            p.chunks(8).map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap()))).collect();
        assert_eq!(vals, vec![1.0, 3.0, 2.0, 4.0]);
        assert_eq!(unpack_matrix(&p, 2, 2, FP64, MatrixOrder::ColMajor), data);
    }

    #[test]
    fn quantization_snaps_to_grid() {
        let q = quantize_f64(&[1.1, 0.3], FP8);
        assert_eq!(q, vec![1.0, 0.3125]);
    }
}
