//! `repro` — the reproduction CLI.
//!
//! One subcommand per paper table/figure plus the end-to-end training
//! driver. Run `repro help` for the list.

use minifloat_nn::api::{self, Session};
use minifloat_nn::coordinator::Precision;
use minifloat_nn::nn::{Activation, DataSpec, OptimSpec, PrecisionPolicy};
use minifloat_nn::report;
use minifloat_nn::serve::{sim, InferenceModel};
use minifloat_nn::util::cli::Args;
use minifloat_nn::util::error::Result;
use minifloat_nn::{bail, ensure};

const HELP: &str = "\
repro — reproduction of 'MiniFloat-NN and ExSdotp' (Bertaccini et al., 2022)

USAGE: repro <command> [options]

Paper artifacts:
  table1            supported format combinations of the ExSdotp unit
  table2            GEMM cycle counts on the simulated 8-core cluster
  table3            FPU/cluster performance + energy-efficiency rows
  table4            accuracy of ExSdotp vs ExFMA cascade vs FP64 golden
  fig7a             fused-vs-cascade area/critical-path model
  fig7b             extended-FPU area breakdown + cluster area
  fig8              FLOP/cycle chart for all kernels and sizes
  formats           Fig. 1 format table
  fig2              register-file utilization argument
  all               everything above, in order

Workloads:
  gemm              run one GEMM      [--size 128x128] [--kernel fp64|fp32|fp16|fp16to32|fp8]
                    [--mode functional|cycle]  (functional = batch engine, bit-identical C)
  roofline          multi-cluster SoC sweep: FLOP/cycle + GFLOPS/W vs cluster count
                    [--clusters 1,2,4,8]  comma-separated counts, each 1..=8
                    [--size 128x256] [--k 128] [--pairs fp8,fp16to32]
                    [--mode functional|cycle] [--json]
                    [--check-anchor]  gate the 1-cluster FP8 row against the energy
                                      model's 575 GFLOPS/W anchor within 1% (exit 1)

Numerics:
  accuracy          accuracy-at-scale matrix: spiral training per policy (incl. the
                    stochastic-rounding fp8sr / scaled fp8flex recipes) + big-K
                    FP8->FP16 dot probe {naive, chunked} vs an f64 reference, and
                    the SR bit-determinism check across thread budgets; exits 1
                    when a gate fails (SR determinism, fp8sr within 3 accuracy
                    points of fp32)
                    [--steps N]  training steps per policy row (default 300)
                    [--seed S] [--json]

End-to-end training:
  train             mixed-precision training on the minifloat batch engine
                    [--engine native|pjrt]  (default native: offline, every matmul a GemmPlan)
                    [--precision fp32|fp16|fp16alt|fp8|hfp8|fp8sr|fp8flex]  (default hfp8)
                    [--steps N] [--dataset spiral|rings] [--hidden H] [--batch B]
                    [--optim adam|sgd] [--lr X] [--act relu|gelu] [--seed S] [--quiet]
                    [--save FILE]  (freeze the trained model into a serving checkpoint)
                    (--engine pjrt drives the AOT artifacts instead; needs `make artifacts`
                     and a PJRT-enabled build; [--artifacts DIR], hfp8|fp32 only)

Serving:
  serve             multi-tenant batched inference serving (virtual time, deterministic)
                    [--tenants P1,P2,...]  comma-separated precision policies, one tenant
                                           each, trained in-process (default hfp8,fp32)
                    [--checkpoint FILE]    serve a saved model instead (see train --save)
                    [--requests N] [--max-batch B] [--max-wait T] [--shards S]
                    [--batching continuous|whole]  wave scheduling mode (default
                                           continuous; whole = legacy run-to-completion)
                    [--queue-cap N]        bound each tenant queue; overflow is shed
                                           (0 = unbounded, the default)
                    [--rate-limit R]       per-tenant token bucket, R requests/tick
                                           sustained (0 = off); [--burst B] headroom
                                           (default --max-batch)
                    [--load open|bursty|closed] [--clients N] [--deadline T]
                    [--rate R]  mean arrivals per tick (open and bursty loops)
                    [--on-ticks T] [--off-ticks T]  bursty ON/OFF dwell means
                                           (defaults 8, 32)
                    [--train-steps N] [--seed S] [--json]

Options:
  --seed S          RNG seed for simulated workloads (default 42)
  --metrics         (gemm|roofline|train|serve|accuracy) append the deterministic
                    observability roll-up; the final stdout line is the
                    byte-stable metrics snapshot JSON (merged into the
                    --json object where one exists)
  --trace FILE      (gemm|roofline|train|serve|accuracy) write a Chrome trace-event
                    JSON of the run (open in chrome://tracing / Perfetto)
";

/// Parsed observability flags. Both are strict: a bare `--trace` (no
/// path) and a valued `--metrics` are typed errors up front, and the
/// trace path is created before any simulated work runs, so a bad path
/// fails in milliseconds, not after minutes of GEMM.
struct ObsOpts {
    metrics: bool,
    trace_path: Option<String>,
}

fn obs_setup(args: &Args) -> Result<ObsOpts> {
    ensure!(
        !args.has_flag("trace"),
        "--trace needs a file path (usage: --trace FILE)"
    );
    ensure!(
        !args.options.contains_key("metrics"),
        "--metrics takes no value (got '--metrics {}')",
        args.options["metrics"]
    );
    let metrics = args.has_flag("metrics");
    let trace_path = args.options.get("trace").cloned();
    if let Some(path) = &trace_path {
        std::fs::File::create(path).map_err(|e| {
            minifloat_nn::util::error::Error::msg(format!(
                "--trace: cannot create '{path}': {e}"
            ))
        })?;
    }
    if metrics || trace_path.is_some() {
        minifloat_nn::obs::reset_all();
        minifloat_nn::obs::metrics::enable(metrics);
        minifloat_nn::obs::trace::enable(trace_path.is_some());
    }
    Ok(ObsOpts { metrics, trace_path })
}

impl ObsOpts {
    /// Write the trace file if requested (note to stderr — `--json`
    /// stdout must stay one parseable line).
    fn write_trace(&self) -> Result<()> {
        if let Some(path) = &self.trace_path {
            minifloat_nn::obs::trace::write_chrome_trace(path).map_err(|e| {
                minifloat_nn::util::error::Error::msg(format!(
                    "--trace: cannot write '{path}': {e}"
                ))
            })?;
            eprintln!(
                "trace written to {path} ({} events, {} dropped)",
                minifloat_nn::obs::trace::len(),
                minifloat_nn::obs::trace::dropped()
            );
        }
        Ok(())
    }

    /// Append the human roll-up and the byte-stable snapshot line to
    /// stdout (the non-`--json` metrics epilogue; the snapshot is
    /// always the final line so scripts can `tail -n1`).
    fn print_metrics(&self) {
        if self.metrics {
            let snap = minifloat_nn::obs::metrics::snapshot();
            print!("{}", report::obs_text(&snap));
            println!("{}", report::obs_json(&snap));
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    // Strict: a typo'd seed must not silently become the default — the
    // serving/accuracy workloads advertise seeded reproducibility.
    let seed: u64 = args.try_get("seed", 42)?;
    match args.command.as_deref() {
        Some("table1") => print!("{}", report::table1_text()),
        Some("table2") => {
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
        }
        Some("table3") => print!("{}", report::table3_text(seed)),
        Some("table4") => print!("{}", report::table4_text(seed)),
        Some("fig7a") => print!("{}", report::fig7a_text()),
        Some("fig7b") => print!("{}", report::fig7b_text()),
        Some("fig8") => {
            let rows = report::run_table2(seed);
            print!("{}", report::fig8_text(&rows));
        }
        Some("formats") => print!("{}", report::formats_text()),
        Some("fig2") => print!("{}", report::fig2_text()),
        Some("gemm") => {
            use minifloat_nn::kernels::reference_gemm_f64;
            // All argument validation happens in the typed API: parse
            // helpers for the flags, the plan builder for the problem
            // (format pair, divisibility, TCDM feasibility) — bad input
            // is a typed error and a nonzero exit, never a panic.
            let obs = obs_setup(&args)?;
            let (m, n) = api::parse_size(&args.get_str("size", "128x128"))?;
            let k = m;
            let kind = api::parse_kernel(&args.get_str("kernel", "fp8"))?;
            let mode = api::parse_mode(&args.get_str("mode", "functional"))?;
            let session = Session::builder().mode(mode).seed(seed).build();
            let plan = session.gemm().kind(kind).dims(m, n, k)?;
            let mut rng = session.rng();
            let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
            let run = plan.run_f64(&a, &b)?;
            let gold = reference_gemm_f64(&a, &b, m, n, k);
            let c = run.c_f64();
            let mut worst = 0f64;
            for (g, r) in gold.iter().zip(&c) {
                worst = worst.max((g - r).abs() / g.abs().max(1.0));
            }
            println!("kernel {}   size {m}x{n} (K={k})   mode {mode:?}", kind.label());
            match run.cycles {
                Some(cy) => println!("cycles       : {cy} ({})", run.timing_label()),
                None => println!("cycles       : - (cycle model disabled)"),
            }
            println!("FLOP         : {}", run.flops);
            println!("FLOP/cycle   : {:.2}", run.flop_per_cycle().unwrap_or(0.0));
            println!("wall time    : {:.3} ms", run.wall.as_secs_f64() * 1e3);
            // |Δ|/max(|gold|,1): relative error for large outputs,
            // absolute for near-zero ones (a pure ratio blows up there).
            println!("worst |err|/max(|gold|,1) vs f64: {worst:.3e}");
            obs.write_trace()?;
            obs.print_metrics();
        }
        Some("roofline") => {
            // Same strictness contract as `serve`: every flag parses
            // up front with a typed error and exit code 1 on bad input.
            let obs = obs_setup(&args)?;
            let (m, n) = api::parse_size(&args.get_str("size", "128x256"))?;
            let k: usize = args.try_get("k", 128)?;
            let mode = api::parse_mode(&args.get_str("mode", "cycle"))?;
            let spec = args.get_str("clusters", "1,2,4,8");
            let mut clusters = Vec::new();
            for tok in spec.split(',') {
                let tok = tok.trim();
                let nc: usize = tok.parse().map_err(|_| {
                    minifloat_nn::util::error::Error::msg(format!(
                        "--clusters must be a comma-separated list of counts, got '{tok}'"
                    ))
                })?;
                ensure!(
                    (1..=8).contains(&nc),
                    "--clusters entries must be 1..=8 (the paper's scale-out range), got {nc}"
                );
                if !clusters.contains(&nc) {
                    clusters.push(nc);
                }
            }
            let mut kinds = Vec::new();
            for tok in args.get_str("pairs", "fp8,fp16to32").split(',') {
                let kind = api::parse_kernel(tok.trim())?;
                if !kinds.contains(&kind) {
                    kinds.push(kind);
                }
            }
            if args.has_flag("check-anchor") {
                ensure!(
                    mode == minifloat_nn::kernels::ExecMode::CycleAccurate,
                    "--check-anchor needs op counters and only the cycle-accurate mode \
                     collects them; drop --mode functional"
                );
                // Progress to stderr so --json leaves stdout one line.
                eprintln!("checking the 575 GFLOPS/W anchor at 1 cluster...");
                let chk = minifloat_nn::soc::roofline::check_anchor(seed)?;
                eprintln!(
                    "anchor: SoC {:.1} vs direct {:.1} GFLOPS/W ({:.3}% apart)",
                    chk.soc_gflops_per_w,
                    chk.direct_gflops_per_w,
                    chk.rel_err * 100.0
                );
                ensure!(
                    chk.rel_err < 0.01,
                    "SoC single-cluster efficiency {:.2} GFLOPS/W drifted {:.2}% from the \
                     energy model's {:.2} (gate: 1%)",
                    chk.soc_gflops_per_w,
                    chk.rel_err * 100.0,
                    chk.direct_gflops_per_w
                );
                ensure!(
                    (chk.direct_gflops_per_w - 575.0).abs() < 60.0,
                    "anchor efficiency {:.0} GFLOPS/W left the paper's 575 band",
                    chk.direct_gflops_per_w
                );
            }
            let rows = minifloat_nn::soc::run_roofline(&clusters, &kinds, m, n, k, mode, seed)?;
            obs.write_trace()?;
            if args.has_flag("json") {
                let mut line = report::roofline_json(&rows);
                if obs.metrics {
                    // Merge the snapshot into the existing one-line
                    // object: {"roofline":[...],"obs":{...}}.
                    line.pop();
                    line.push_str(",\"obs\":");
                    line.push_str(&minifloat_nn::obs::metrics::snapshot_json());
                    line.push('}');
                }
                println!("{line}");
            } else {
                print!("{}", report::roofline_text(&rows));
                obs.print_metrics();
            }
        }
        Some("all") => {
            print!("{}", report::formats_text());
            println!();
            print!("{}", report::fig2_text());
            println!();
            print!("{}", report::table1_text());
            println!();
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
            println!();
            print!("{}", report::fig8_text(&rows));
            println!();
            print!("{}", report::fig7a_text());
            println!();
            print!("{}", report::fig7b_text());
            println!();
            print!("{}", report::table3_text(seed));
            println!();
            print!("{}", report::table4_text(seed));
        }
        Some("accuracy") => {
            let obs = obs_setup(&args)?;
            let steps: usize = args.try_get("steps", 300)?;
            ensure!(steps > 0, "--steps must be positive");
            // Progress to stderr: --json leaves stdout one line.
            eprintln!("accuracy matrix: 7 policy rows x {steps} steps + big-K dot probe...");
            let sweep = minifloat_nn::numerics::run_sweep(steps, seed)?;
            obs.write_trace()?;
            if args.has_flag("json") {
                let mut line = report::accuracy_json(&sweep);
                if obs.metrics {
                    line.pop();
                    line.push_str(",\"obs\":");
                    line.push_str(&minifloat_nn::obs::metrics::snapshot_json());
                    line.push('}');
                }
                println!("{line}");
            } else {
                print!("{}", report::accuracy_text(&sweep));
                obs.print_metrics();
            }
            // Gates last, after every requested output is flushed, so a
            // failing run still leaves the full record behind.
            sweep.check_gates()?;
        }
        Some("train") => {
            let obs = obs_setup(&args)?;
            let log_every = if args.has_flag("quiet") { 0 } else { 20 };
            match api::parse_engine(&args.get_str("engine", "native"))? {
                api::TrainEngine::Native => {
                    let steps: usize = args.try_get("steps", 500)?;
                    let policy = api::parse_policy(&args.get_str("precision", "hfp8"))?;
                    let lr: f64 = args.try_get("lr", 4e-3)?;
                    let optim = match args.get_str("optim", "adam").as_str() {
                        "adam" => OptimSpec::adam(lr),
                        "sgd" => OptimSpec::sgd(lr),
                        other => {
                            return Err(minifloat_nn::util::error::Error::msg(format!(
                                "--optim must be adam|sgd, got '{other}'"
                            )))
                        }
                    };
                    let session = Session::builder().seed(seed).build();
                    let mut tr = session
                        .train()
                        .policy(policy)
                        .dataset(DataSpec::parse(&args.get_str("dataset", "spiral"))?)
                        .hidden(args.try_get("hidden", 32)?)
                        .batch(args.try_get("batch", 64)?)
                        .activation(Activation::parse(&args.get_str("act", "relu"))?)
                        .optimizer(optim)
                        .build()?
                        .trainer()?;
                    println!(
                        "native training: policy {} ({} fwd / {} bwd, {} acc), {steps} steps",
                        policy.name,
                        policy.fwd.name(),
                        policy.bwd.name(),
                        policy.acc.name()
                    );
                    let final_loss = tr.train(steps, log_every)?;
                    let acc = tr.accuracy()?;
                    print!("{}", report::train_curve_text(&tr.history));
                    println!(
                        "final loss {final_loss:.4}   accuracy {:.1}%   ({} GemmPlan runs, \
                         {:.0}% packed fast path, {} plan instances compiled / {} reused, \
                         {} skipped steps, loss scale {})",
                        acc * 100.0,
                        tr.gemm_calls(),
                        100.0 * tr.packed_runs() as f64 / tr.gemm_calls().max(1) as f64,
                        tr.plan_builds(),
                        tr.plan_reuses(),
                        tr.skipped_steps(),
                        tr.loss_scale()
                    );
                    if let Some(path) = args.options.get("save") {
                        let frozen = InferenceModel::freeze(tr.session(), tr.model(), tr.policy())?;
                        frozen.save(path)?;
                        println!(
                            "checkpoint saved to {path} ({} layers, policy {}) — serve it with \
                             `repro serve --checkpoint {path}`",
                            frozen.layers().len(),
                            frozen.policy().name
                        );
                    }
                }
                api::TrainEngine::Pjrt => {
                    let steps: usize = args.try_get("steps", 300)?;
                    let dir = args.get_str("artifacts", "artifacts");
                    let precision = match args.get_str("precision", "hfp8").as_str() {
                        "fp32" => Precision::Fp32,
                        "hfp8" => Precision::Hfp8,
                        other => {
                            return Err(minifloat_nn::util::error::Error::msg(format!(
                                "--engine pjrt compiles artifacts for hfp8|fp32 only, got \
                                 '{other}' (the native engine supports every policy)"
                            )))
                        }
                    };
                    println!("training ({precision:?}) for {steps} steps on the spiral task...");
                    let mut tr = Session::builder().seed(seed).build().trainer(&dir, precision)?;
                    let final_loss = tr.train(steps, log_every)?;
                    let acc = tr.accuracy()?;
                    println!("final loss {final_loss:.4}   accuracy {:.1}%", acc * 100.0);
                }
            }
            obs.write_trace()?;
            obs.print_metrics();
        }
        Some("serve") => {
            // All argument validation is typed: numeric flags parse
            // strictly up front (a typo is an error, not a silent
            // default), everything structural in the ServePlanBuilder —
            // bad input is exit code 1 with a message, never a panic.
            let obs = obs_setup(&args)?;
            let max_batch: usize = args.try_get("max-batch", 32)?;
            let max_wait: u64 = args.try_get("max-wait", 4)?;
            let shards: usize = args.try_get("shards", 4)?;
            let requests: usize = args.try_get("requests", 512)?;
            let deadline: u64 = args.try_get("deadline", 0)?;
            let deadline = (deadline > 0).then_some(deadline);
            let batching =
                minifloat_nn::serve::BatchMode::parse(&args.get_str("batching", "continuous"))?;
            // 0 = unbounded / off, the defaults.
            let queue_cap: usize = args.try_get("queue-cap", 0)?;
            let rate_limit: f64 = args.try_get("rate-limit", 0.0)?;
            let burst: u64 = args.try_get("burst", 0)?;
            // Reject out-of-range knobs *before* the tenant-training
            // loop spends seconds of GEMM work.
            minifloat_nn::api::serve::validate_knobs(max_batch, max_wait, shards)?;
            if queue_cap > 0 {
                minifloat_nn::api::serve::validate_queue_cap(queue_cap)?;
            }
            ensure!(
                rate_limit == 0.0 || (rate_limit.is_finite() && rate_limit > 0.0),
                "--rate-limit must be a positive requests-per-tick budget (0 = off), got \
                 {rate_limit}"
            );
            let session = Session::builder().seed(seed).build();
            let mut tenants: Vec<(String, InferenceModel)> = Vec::new();
            if let Some(path) = args.options.get("checkpoint") {
                ensure!(
                    !args.options.contains_key("tenants") && !args.options.contains_key("train-steps"),
                    "--checkpoint serves the saved model alone; it conflicts with \
                     --tenants/--train-steps (drop one or the other)"
                );
                let model = InferenceModel::load(&session, path)?;
                tenants.push((model.policy().name.to_string(), model));
            } else {
                let spec = args.get_str("tenants", "hfp8,fp32");
                let train_steps: usize = args.try_get("train-steps", 120)?;
                ensure!(train_steps > 0, "--train-steps must be positive");
                for name in spec.split(',') {
                    let name = name.trim();
                    if name.is_empty() {
                        bail!(
                            "--tenants must be a non-empty comma-separated list of \
                             fp32|fp16|fp16alt|fp8|hfp8|fp8sr|fp8flex, got '{spec}'"
                        );
                    }
                    let policy = PrecisionPolicy::parse(name).map_err(|_| {
                        minifloat_nn::util::error::Error::msg(format!(
                            "--tenants must list precision policies \
                             (fp32|fp16|fp16alt|fp8|hfp8|fp8sr|fp8flex), got '{name}'"
                        ))
                    })?;
                    if tenants.iter().any(|(n, _)| n == name) {
                        bail!("--tenants lists '{name}' twice; tenant names must be unique");
                    }
                    // Per-tenant seed salt so tenants do not share weights.
                    let tseed = seed ^ (0x5E21 + tenants.len() as u64);
                    let tsession = Session::builder().seed(tseed).build();
                    let mut tr = tsession.native_trainer(policy)?;
                    // Progress goes to stderr so `--json` leaves stdout
                    // as one parseable JSON line.
                    eprintln!("training tenant '{name}' for {train_steps} steps...");
                    tr.train(train_steps, 0)?;
                    tenants.push((name.to_string(), InferenceModel::freeze(&session, tr.model(), tr.policy())?));
                }
            }
            let mut builder = session
                .server()
                .max_batch(max_batch)
                .max_wait_ticks(max_wait)
                .shards(shards)
                .batching(batching);
            if queue_cap > 0 {
                builder = builder.queue_cap(queue_cap);
            }
            let tenant_names: Vec<String> = tenants.iter().map(|(n, _)| n.clone()).collect();
            for (name, model) in tenants {
                builder = builder.tenant(&name, model);
            }
            if rate_limit > 0.0 {
                // One uniform bucket per tenant; --burst defaults to the
                // batch size so one full wave of headroom is spendable.
                let burst = if burst > 0 { burst } else { max_batch as u64 };
                for name in &tenant_names {
                    builder = builder.rate_limit(name, rate_limit, burst);
                }
            }
            let plan = builder.build()?;
            let mut server = plan.server();
            let in_dims: Vec<usize> =
                server.tenants().iter().map(|t| t.model.in_dim()).collect();
            let responses = match args.get_str("load", "open").as_str() {
                "open" => {
                    let rate: f64 = args.try_get("rate", 4.0)?;
                    ensure!(
                        rate.is_finite() && rate > 0.0,
                        "--rate must be a positive arrival rate per tick, got {rate}"
                    );
                    let trace = sim::Trace::open_loop(
                        seed ^ 0x7E1,
                        &in_dims,
                        requests,
                        1.0 / rate,
                        deadline,
                    )?;
                    sim::replay(&mut server, &trace)?
                }
                "bursty" => {
                    let rate: f64 = args.try_get("rate", 4.0)?;
                    ensure!(
                        rate.is_finite() && rate > 0.0,
                        "--rate must be a positive arrival rate per tick, got {rate}"
                    );
                    let on_ticks: f64 = args.try_get("on-ticks", 8.0)?;
                    let off_ticks: f64 = args.try_get("off-ticks", 32.0)?;
                    let trace = sim::Trace::bursty(
                        seed ^ 0x7E1,
                        &in_dims,
                        requests,
                        1.0 / rate,
                        on_ticks,
                        off_ticks,
                        deadline,
                    )?;
                    sim::replay(&mut server, &trace)?
                }
                "closed" => {
                    let clients: usize = args.try_get("clients", 16)?;
                    sim::closed_loop(&mut server, clients, requests, 1, seed ^ 0x7E1, deadline)?
                }
                other => bail!("--load must be open|bursty|closed, got '{other}'"),
            };
            let names: Vec<String> =
                server.tenants().iter().map(|t| t.name.clone()).collect();
            obs.write_trace()?;
            if args.has_flag("json") {
                if obs.metrics {
                    // One parseable line either way: wrap the two views
                    // side by side so their shared quantities (batches,
                    // deadline misses) can be cross-checked downstream.
                    println!(
                        "{{\"serve\":{},\"obs\":{}}}",
                        server.stats().summary_json(),
                        minifloat_nn::obs::metrics::snapshot_json()
                    );
                } else {
                    println!("{}", server.stats().summary_json());
                }
            } else {
                println!(
                    "served {} responses over {} virtual ticks ({} tenants, {} shards, \
                     {} batching, max batch {}, max wait {})",
                    responses.len(),
                    server.now(),
                    names.len(),
                    server.shard_count(),
                    plan.batch_mode().name(),
                    plan.batch_policy().max_batch,
                    plan.batch_policy().max_wait_ticks
                );
                print!("{}", report::serve_stats_text(server.stats(), &names));
                obs.print_metrics();
            }
        }
        _ => print!("{HELP}"),
    }
    Ok(())
}
