//! `repro` — the reproduction CLI.
//!
//! One subcommand per paper table/figure plus the end-to-end training
//! driver. Run `repro help` for the list.

use minifloat_nn::coordinator::{Precision, Trainer};
use minifloat_nn::report;
use minifloat_nn::util::cli::Args;
use minifloat_nn::util::error::Result;

const HELP: &str = "\
repro — reproduction of 'MiniFloat-NN and ExSdotp' (Bertaccini et al., 2022)

USAGE: repro <command> [options]

Paper artifacts:
  table1            supported format combinations of the ExSdotp unit
  table2            GEMM cycle counts on the simulated 8-core cluster
  table3            FPU/cluster performance + energy-efficiency rows
  table4            accuracy of ExSdotp vs ExFMA cascade vs FP64 golden
  fig7a             fused-vs-cascade area/critical-path model
  fig7b             extended-FPU area breakdown + cluster area
  fig8              FLOP/cycle chart for all kernels and sizes
  formats           Fig. 1 format table
  fig2              register-file utilization argument
  all               everything above, in order

Workloads:
  gemm              run one GEMM      [--size 128x128] [--kernel fp64|fp32|fp16|fp16to32|fp8]
                    [--mode functional|cycle]  (functional = batch engine, bit-identical C)

End-to-end (three-layer stack, artifacts required — `make artifacts`):
  train             train the HFP8 MLP via PJRT   [--steps N] [--precision hfp8|fp32]
                    [--seed S] [--artifacts DIR] [--quiet]

Options:
  --seed S          RNG seed for simulated workloads (default 42)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.get("seed", 42);
    match args.command.as_deref() {
        Some("table1") => print!("{}", report::table1_text()),
        Some("table2") => {
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
        }
        Some("table3") => print!("{}", report::table3_text(seed)),
        Some("table4") => print!("{}", report::table4_text(seed)),
        Some("fig7a") => print!("{}", report::fig7a_text()),
        Some("fig7b") => print!("{}", report::fig7b_text()),
        Some("fig8") => {
            let rows = report::run_table2(seed);
            print!("{}", report::fig8_text(&rows));
        }
        Some("formats") => print!("{}", report::formats_text()),
        Some("fig2") => print!("{}", report::fig2_text()),
        Some("gemm") => {
            use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
            use minifloat_nn::kernels::{reference_gemm_f64, ExecMode, GemmKernel, GemmKind};
            use minifloat_nn::util::rng::Rng;
            let size = args.get_str("size", "128x128");
            let Some((m, n)) = size
                .split_once('x')
                .and_then(|(a, b)| Some((a.parse::<usize>().ok()?, b.parse::<usize>().ok()?)))
            else {
                minifloat_nn::bail!("--size must be MxN (e.g. 128x128), got '{size}'");
            };
            let k = m;
            let kernel = args.get_str("kernel", "fp8");
            let kind = match kernel.as_str() {
                "fp64" => GemmKind::FmaF64,
                "fp32" => GemmKind::FmaSimd(ScalarFmt::S),
                "fp16" => GemmKind::FmaSimd(ScalarFmt::H),
                "fp16to32" => GemmKind::ExSdotp(OpWidth::HtoS),
                "fp8" => GemmKind::ExSdotp(OpWidth::BtoH),
                other => minifloat_nn::bail!("--kernel must be fp64|fp32|fp16|fp16to32|fp8, got '{other}'"),
            };
            let mode_s = args.get_str("mode", "functional");
            let mode = match mode_s.as_str() {
                "cycle" => ExecMode::CycleAccurate,
                "functional" => ExecMode::Functional,
                other => minifloat_nn::bail!("--mode must be functional|cycle, got '{other}'"),
            };
            // Validate the kernel's divisibility constraints up front so
            // bad sizes produce a CLI error, not a panic.
            minifloat_nn::ensure!(m > 0 && m % 8 == 0, "M ({m}) must be a positive multiple of 8 (compute cores)");
            minifloat_nn::ensure!(
                n > 0 && n % kind.unroll() == 0,
                "N ({n}) must be a positive multiple of the kernel's unroll factor ({})",
                kind.unroll()
            );
            minifloat_nn::ensure!(
                k % kind.lanes() == 0,
                "K ({k}) must be a multiple of the kernel's SIMD width ({})",
                kind.lanes()
            );
            let kern = GemmKernel::new(kind, m, n, k);
            if mode == ExecMode::CycleAccurate {
                minifloat_nn::ensure!(
                    kern.footprint() <= 128 * 1024,
                    "{} {} does not fit the simulated 128 kB TCDM; use --mode functional for larger problems",
                    kind.label(),
                    kern.size_label()
                );
            }
            let mut rng = Rng::new(seed);
            let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
            let t0 = std::time::Instant::now();
            let run = kern.run_mode(&a, &b, mode);
            let wall = t0.elapsed();
            let gold = reference_gemm_f64(&a, &b, m, n, k);
            let mut worst = 0f64;
            for (g, r) in gold.iter().zip(&run.c) {
                worst = worst.max((g - r).abs() / g.abs().max(1.0));
            }
            println!("kernel {}   size {m}x{n} (K={k})   mode {mode:?}", kind.label());
            println!("cycles       : {} ({})", run.cycles, match mode {
                ExecMode::CycleAccurate => "simulated",
                ExecMode::Functional => "issue-slot model",
            });
            println!("FLOP         : {}", run.flops);
            println!("FLOP/cycle   : {:.2}", run.flop_per_cycle());
            println!("wall time    : {:.3} ms", wall.as_secs_f64() * 1e3);
            // |Δ|/max(|gold|,1): relative error for large outputs,
            // absolute for near-zero ones (a pure ratio blows up there).
            println!("worst |err|/max(|gold|,1) vs f64: {worst:.3e}");
        }
        Some("all") => {
            print!("{}", report::formats_text());
            println!();
            print!("{}", report::fig2_text());
            println!();
            print!("{}", report::table1_text());
            println!();
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
            println!();
            print!("{}", report::fig8_text(&rows));
            println!();
            print!("{}", report::fig7a_text());
            println!();
            print!("{}", report::fig7b_text());
            println!();
            print!("{}", report::table3_text(seed));
            println!();
            print!("{}", report::table4_text(seed));
        }
        Some("train") => {
            let steps: usize = args.get("steps", 300);
            let dir = args.get_str("artifacts", "artifacts");
            let precision = match args.get_str("precision", "hfp8").as_str() {
                "fp32" => Precision::Fp32,
                _ => Precision::Hfp8,
            };
            let log_every = if args.has_flag("quiet") { 0 } else { 20 };
            println!("training ({precision:?}) for {steps} steps on the spiral task...");
            let mut tr = Trainer::new(&dir, precision, seed)?;
            let final_loss = tr.train(steps, log_every)?;
            let acc = tr.accuracy()?;
            println!("final loss {final_loss:.4}   accuracy {:.1}%", acc * 100.0);
        }
        _ => print!("{HELP}"),
    }
    Ok(())
}
