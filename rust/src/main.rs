//! `repro` — the reproduction CLI.
//!
//! One subcommand per paper table/figure plus the end-to-end training
//! driver. Run `repro help` for the list.

use minifloat_nn::api::{self, Session};
use minifloat_nn::coordinator::Precision;
use minifloat_nn::nn::{Activation, DataSpec, OptimSpec};
use minifloat_nn::report;
use minifloat_nn::util::cli::Args;
use minifloat_nn::util::error::Result;

const HELP: &str = "\
repro — reproduction of 'MiniFloat-NN and ExSdotp' (Bertaccini et al., 2022)

USAGE: repro <command> [options]

Paper artifacts:
  table1            supported format combinations of the ExSdotp unit
  table2            GEMM cycle counts on the simulated 8-core cluster
  table3            FPU/cluster performance + energy-efficiency rows
  table4            accuracy of ExSdotp vs ExFMA cascade vs FP64 golden
  fig7a             fused-vs-cascade area/critical-path model
  fig7b             extended-FPU area breakdown + cluster area
  fig8              FLOP/cycle chart for all kernels and sizes
  formats           Fig. 1 format table
  fig2              register-file utilization argument
  all               everything above, in order

Workloads:
  gemm              run one GEMM      [--size 128x128] [--kernel fp64|fp32|fp16|fp16to32|fp8]
                    [--mode functional|cycle]  (functional = batch engine, bit-identical C)

End-to-end training:
  train             mixed-precision training on the minifloat batch engine
                    [--engine native|pjrt]  (default native: offline, every matmul a GemmPlan)
                    [--precision fp32|fp16|fp16alt|fp8|hfp8]  (default hfp8)
                    [--steps N] [--dataset spiral|rings] [--hidden H] [--batch B]
                    [--optim adam|sgd] [--lr X] [--act relu|gelu] [--seed S] [--quiet]
                    (--engine pjrt drives the AOT artifacts instead; needs `make artifacts`
                     and a PJRT-enabled build; [--artifacts DIR], hfp8|fp32 only)

Options:
  --seed S          RNG seed for simulated workloads (default 42)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.get("seed", 42);
    match args.command.as_deref() {
        Some("table1") => print!("{}", report::table1_text()),
        Some("table2") => {
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
        }
        Some("table3") => print!("{}", report::table3_text(seed)),
        Some("table4") => print!("{}", report::table4_text(seed)),
        Some("fig7a") => print!("{}", report::fig7a_text()),
        Some("fig7b") => print!("{}", report::fig7b_text()),
        Some("fig8") => {
            let rows = report::run_table2(seed);
            print!("{}", report::fig8_text(&rows));
        }
        Some("formats") => print!("{}", report::formats_text()),
        Some("fig2") => print!("{}", report::fig2_text()),
        Some("gemm") => {
            use minifloat_nn::kernels::reference_gemm_f64;
            // All argument validation happens in the typed API: parse
            // helpers for the flags, the plan builder for the problem
            // (format pair, divisibility, TCDM feasibility) — bad input
            // is a typed error and a nonzero exit, never a panic.
            let (m, n) = api::parse_size(&args.get_str("size", "128x128"))?;
            let k = m;
            let kind = api::parse_kernel(&args.get_str("kernel", "fp8"))?;
            let mode = api::parse_mode(&args.get_str("mode", "functional"))?;
            let session = Session::builder().mode(mode).seed(seed).build();
            let plan = session.gemm().kind(kind).dims(m, n, k)?;
            let mut rng = session.rng();
            let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
            let run = plan.run_f64(&a, &b)?;
            let gold = reference_gemm_f64(&a, &b, m, n, k);
            let c = run.c_f64();
            let mut worst = 0f64;
            for (g, r) in gold.iter().zip(&c) {
                worst = worst.max((g - r).abs() / g.abs().max(1.0));
            }
            println!("kernel {}   size {m}x{n} (K={k})   mode {mode:?}", kind.label());
            match run.cycles {
                Some(cy) => println!("cycles       : {cy} ({})", run.timing_label()),
                None => println!("cycles       : - (cycle model disabled)"),
            }
            println!("FLOP         : {}", run.flops);
            println!("FLOP/cycle   : {:.2}", run.flop_per_cycle().unwrap_or(0.0));
            println!("wall time    : {:.3} ms", run.wall.as_secs_f64() * 1e3);
            // |Δ|/max(|gold|,1): relative error for large outputs,
            // absolute for near-zero ones (a pure ratio blows up there).
            println!("worst |err|/max(|gold|,1) vs f64: {worst:.3e}");
        }
        Some("all") => {
            print!("{}", report::formats_text());
            println!();
            print!("{}", report::fig2_text());
            println!();
            print!("{}", report::table1_text());
            println!();
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
            println!();
            print!("{}", report::fig8_text(&rows));
            println!();
            print!("{}", report::fig7a_text());
            println!();
            print!("{}", report::fig7b_text());
            println!();
            print!("{}", report::table3_text(seed));
            println!();
            print!("{}", report::table4_text(seed));
        }
        Some("train") => {
            let log_every = if args.has_flag("quiet") { 0 } else { 20 };
            match api::parse_engine(&args.get_str("engine", "native"))? {
                api::TrainEngine::Native => {
                    let steps: usize = args.get("steps", 500);
                    let policy = api::parse_policy(&args.get_str("precision", "hfp8"))?;
                    let lr: f64 = args.get("lr", 4e-3);
                    let optim = match args.get_str("optim", "adam").as_str() {
                        "adam" => OptimSpec::adam(lr),
                        "sgd" => OptimSpec::sgd(lr),
                        other => {
                            return Err(minifloat_nn::util::error::Error::msg(format!(
                                "--optim must be adam|sgd, got '{other}'"
                            )))
                        }
                    };
                    let session = Session::builder().seed(seed).build();
                    let mut tr = session
                        .train()
                        .policy(policy)
                        .dataset(DataSpec::parse(&args.get_str("dataset", "spiral"))?)
                        .hidden(args.get("hidden", 32))
                        .batch(args.get("batch", 64))
                        .activation(Activation::parse(&args.get_str("act", "relu"))?)
                        .optimizer(optim)
                        .build()?
                        .trainer()?;
                    println!(
                        "native training: policy {} ({} fwd / {} bwd, {} acc), {steps} steps",
                        policy.name,
                        policy.fwd.name(),
                        policy.bwd.name(),
                        policy.acc.name()
                    );
                    let final_loss = tr.train(steps, log_every)?;
                    let acc = tr.accuracy()?;
                    print!("{}", report::train_curve_text(&tr.history));
                    println!(
                        "final loss {final_loss:.4}   accuracy {:.1}%   ({} GemmPlan runs, \
                         {:.0}% packed fast path, {} skipped steps, loss scale {})",
                        acc * 100.0,
                        tr.gemm_calls(),
                        100.0 * tr.packed_runs() as f64 / tr.gemm_calls().max(1) as f64,
                        tr.skipped_steps(),
                        tr.loss_scale()
                    );
                }
                api::TrainEngine::Pjrt => {
                    let steps: usize = args.get("steps", 300);
                    let dir = args.get_str("artifacts", "artifacts");
                    let precision = match args.get_str("precision", "hfp8").as_str() {
                        "fp32" => Precision::Fp32,
                        "hfp8" => Precision::Hfp8,
                        other => {
                            return Err(minifloat_nn::util::error::Error::msg(format!(
                                "--engine pjrt compiles artifacts for hfp8|fp32 only, got \
                                 '{other}' (the native engine supports every policy)"
                            )))
                        }
                    };
                    println!("training ({precision:?}) for {steps} steps on the spiral task...");
                    let mut tr = Session::builder().seed(seed).build().trainer(&dir, precision)?;
                    let final_loss = tr.train(steps, log_every)?;
                    let acc = tr.accuracy()?;
                    println!("final loss {final_loss:.4}   accuracy {:.1}%", acc * 100.0);
                }
            }
        }
        _ => print!("{HELP}"),
    }
    Ok(())
}
