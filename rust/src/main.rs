//! `repro` — the reproduction CLI.
//!
//! One subcommand per paper table/figure plus the end-to-end training
//! driver. Run `repro help` for the list.

use anyhow::Result;
use minifloat_nn::coordinator::{Precision, Trainer};
use minifloat_nn::report;
use minifloat_nn::util::cli::Args;

const HELP: &str = "\
repro — reproduction of 'MiniFloat-NN and ExSdotp' (Bertaccini et al., 2022)

USAGE: repro <command> [options]

Paper artifacts:
  table1            supported format combinations of the ExSdotp unit
  table2            GEMM cycle counts on the simulated 8-core cluster
  table3            FPU/cluster performance + energy-efficiency rows
  table4            accuracy of ExSdotp vs ExFMA cascade vs FP64 golden
  fig7a             fused-vs-cascade area/critical-path model
  fig7b             extended-FPU area breakdown + cluster area
  fig8              FLOP/cycle chart for all kernels and sizes
  formats           Fig. 1 format table
  fig2              register-file utilization argument
  all               everything above, in order

End-to-end (three-layer stack, artifacts required — `make artifacts`):
  train             train the HFP8 MLP via PJRT   [--steps N] [--precision hfp8|fp32]
                    [--seed S] [--artifacts DIR] [--quiet]

Options:
  --seed S          RNG seed for simulated workloads (default 42)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed: u64 = args.get("seed", 42);
    match args.command.as_deref() {
        Some("table1") => print!("{}", report::table1_text()),
        Some("table2") => {
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
        }
        Some("table3") => print!("{}", report::table3_text(seed)),
        Some("table4") => print!("{}", report::table4_text(seed)),
        Some("fig7a") => print!("{}", report::fig7a_text()),
        Some("fig7b") => print!("{}", report::fig7b_text()),
        Some("fig8") => {
            let rows = report::run_table2(seed);
            print!("{}", report::fig8_text(&rows));
        }
        Some("formats") => print!("{}", report::formats_text()),
        Some("fig2") => print!("{}", report::fig2_text()),
        Some("all") => {
            print!("{}", report::formats_text());
            println!();
            print!("{}", report::fig2_text());
            println!();
            print!("{}", report::table1_text());
            println!();
            let rows = report::run_table2(seed);
            print!("{}", report::table2_text(&rows));
            println!();
            print!("{}", report::fig8_text(&rows));
            println!();
            print!("{}", report::fig7a_text());
            println!();
            print!("{}", report::fig7b_text());
            println!();
            print!("{}", report::table3_text(seed));
            println!();
            print!("{}", report::table4_text(seed));
        }
        Some("train") => {
            let steps: usize = args.get("steps", 300);
            let dir = args.get_str("artifacts", "artifacts");
            let precision = match args.get_str("precision", "hfp8").as_str() {
                "fp32" => Precision::Fp32,
                _ => Precision::Hfp8,
            };
            let log_every = if args.has_flag("quiet") { 0 } else { 20 };
            println!("training ({precision:?}) for {steps} steps on the spiral task...");
            let mut tr = Trainer::new(&dir, precision, seed)?;
            let final_loss = tr.train(steps, log_every)?;
            let acc = tr.accuracy()?;
            println!("final loss {final_loss:.4}   accuracy {:.1}%", acc * 100.0);
        }
        _ => print!("{HELP}"),
    }
    Ok(())
}
