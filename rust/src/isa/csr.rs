//! The FP control/status register with the MiniFloat-NN extension bits.
//!
//! §III-E: "Due to the limited encoding space, we did not replicate the
//! same instruction for different FP formats sharing the same width.
//! Instead, the alternative formats – FP16alt and FP8alt – are
//! controlled by two additional bits, `src_is_alt` and `dst_is_alt`, in
//! the FP control and status register. An FP16alt kernel will then
//! differ from an FP16 kernel by a single CSR write."

use crate::formats::{FpFormat, FP16, FP16ALT, FP32, FP64, FP8, FP8ALT};
use crate::isa::instr::{OpWidth, ScalarFmt};
use crate::softfloat::RoundingMode;

/// CSR addresses (fcsr standard + Snitch/MiniFloat-NN custom).
pub mod addr {
    /// Standard `fcsr` (frm+fflags); we expose frm bits 7:5 and the alt
    /// bits at 9:8 (a free custom field).
    pub const FCSR: u16 = 0x003;
    /// Snitch SSR enable CSR.
    pub const SSR: u16 = 0x7c0;
    /// Cluster hardware-barrier CSR (reads stall until all cores arrive).
    pub const BARRIER: u16 = 0x7c2;
    /// Hart id.
    pub const MHARTID: u16 = 0xf14;
}

/// The FP CSR state relevant to the extension.
#[derive(Clone, Copy, Debug)]
pub struct FpCsr {
    /// Dynamic rounding mode.
    pub frm: RoundingMode,
    /// Select FP16alt/FP8alt as the *source* format of width-selected ops.
    pub src_is_alt: bool,
    /// Select FP16alt as the *destination* format of expanding ops.
    pub dst_is_alt: bool,
}

impl Default for FpCsr {
    fn default() -> Self {
        Self { frm: RoundingMode::Rne, src_is_alt: false, dst_is_alt: false }
    }
}

impl FpCsr {
    /// Raw fcsr value (frm at 7:5, src_is_alt bit 8, dst_is_alt bit 9).
    pub fn to_bits(&self) -> u32 {
        (self.frm.to_frm() << 5) | ((self.src_is_alt as u32) << 8) | ((self.dst_is_alt as u32) << 9)
    }

    /// Decode from a raw fcsr value (invalid frm falls back to RNE).
    pub fn from_bits(v: u32) -> Self {
        Self {
            frm: RoundingMode::from_frm((v >> 5) & 0b111).unwrap_or(RoundingMode::Rne),
            src_is_alt: v & (1 << 8) != 0,
            dst_is_alt: v & (1 << 9) != 0,
        }
    }

    /// Resolve the source format of a width-selected SIMD instruction.
    pub fn src_format(&self, w: OpWidth) -> FpFormat {
        match (w, self.src_is_alt) {
            (OpWidth::HtoS, false) => FP16,
            (OpWidth::HtoS, true) => FP16ALT,
            (OpWidth::BtoH, false) => FP8,
            (OpWidth::BtoH, true) => FP8ALT,
        }
    }

    /// Resolve the destination format of an expanding SIMD instruction.
    pub fn dst_format(&self, w: OpWidth) -> FpFormat {
        match (w, self.dst_is_alt) {
            (OpWidth::HtoS, _) => FP32, // FP32 has no alt companion
            (OpWidth::BtoH, false) => FP16,
            (OpWidth::BtoH, true) => FP16ALT,
        }
    }

    /// Resolve a scalar/vectorial format selector (`.h`/`.b` honour
    /// `src_is_alt`).
    pub fn scalar_format(&self, f: ScalarFmt) -> FpFormat {
        match (f, self.src_is_alt) {
            (ScalarFmt::D, _) => FP64,
            (ScalarFmt::S, _) => FP32,
            (ScalarFmt::H, false) => FP16,
            (ScalarFmt::H, true) => FP16ALT,
            (ScalarFmt::B, false) => FP8,
            (ScalarFmt::B, true) => FP8ALT,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_roundtrip() {
        for frm in [RoundingMode::Rne, RoundingMode::Rtz, RoundingMode::Rup] {
            for src_alt in [false, true] {
                for dst_alt in [false, true] {
                    let c = FpCsr { frm, src_is_alt: src_alt, dst_is_alt: dst_alt };
                    let back = FpCsr::from_bits(c.to_bits());
                    assert_eq!(back.frm, frm);
                    assert_eq!(back.src_is_alt, src_alt);
                    assert_eq!(back.dst_is_alt, dst_alt);
                }
            }
        }
    }

    #[test]
    fn alt_bit_retargets_formats_with_one_write() {
        // §III-E's claim: same instruction, different format, one CSR
        // write apart.
        let mut csr = FpCsr::default();
        assert_eq!(csr.src_format(OpWidth::HtoS), FP16);
        assert_eq!(csr.src_format(OpWidth::BtoH), FP8);
        assert_eq!(csr.dst_format(OpWidth::BtoH), FP16);
        csr = FpCsr::from_bits(csr.to_bits() | (1 << 8) | (1 << 9));
        assert_eq!(csr.src_format(OpWidth::HtoS), FP16ALT);
        assert_eq!(csr.src_format(OpWidth::BtoH), FP8ALT);
        assert_eq!(csr.dst_format(OpWidth::BtoH), FP16ALT);
        assert_eq!(csr.dst_format(OpWidth::HtoS), FP32);
    }

    #[test]
    fn scalar_format_resolution() {
        let csr = FpCsr::default();
        assert_eq!(csr.scalar_format(ScalarFmt::D), FP64);
        assert_eq!(csr.scalar_format(ScalarFmt::S), FP32);
        assert_eq!(csr.scalar_format(ScalarFmt::H), FP16);
        let alt = FpCsr { src_is_alt: true, ..FpCsr::default() };
        assert_eq!(alt.scalar_format(ScalarFmt::H), FP16ALT);
        assert_eq!(alt.scalar_format(ScalarFmt::B), FP8ALT);
    }
}
