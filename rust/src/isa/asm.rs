//! Textual assembly: disassembler and a line-oriented assembler.
//!
//! The kernel generators emit `Vec<Instr>` directly; the assembler
//! exists for debugging (dumping generated kernels in readable form,
//! Table II trace inspection) and for writing small test programs by
//! hand. Syntax follows RISC-V conventions with the Snitch/MiniFloat-NN
//! mnemonics (`exsdotp.s.h`, `exvsum.h.b`, `frep.o`, `scfgwi`, ...).

use super::instr::{FReg, Instr, OpWidth, Reg, ScalarFmt};

fn ls_suffix(f: ScalarFmt) -> &'static str {
    match f {
        ScalarFmt::D => "d",
        ScalarFmt::S => "w",
        ScalarFmt::H => "h",
        ScalarFmt::B => "b",
    }
}

fn parse_ls(s: &str) -> Option<ScalarFmt> {
    Some(match s {
        "d" => ScalarFmt::D,
        "w" => ScalarFmt::S,
        "h" => ScalarFmt::H,
        "b" => ScalarFmt::B,
        _ => return None,
    })
}

fn fmt_suffix(f: ScalarFmt) -> &'static str {
    match f {
        ScalarFmt::D => "d",
        ScalarFmt::S => "s",
        ScalarFmt::H => "h",
        ScalarFmt::B => "b",
    }
}

fn parse_fmt(s: &str) -> Option<ScalarFmt> {
    Some(match s {
        "d" => ScalarFmt::D,
        "s" => ScalarFmt::S,
        "h" => ScalarFmt::H,
        "b" => ScalarFmt::B,
        _ => return None,
    })
}

fn width_suffix(w: OpWidth) -> &'static str {
    match w {
        OpWidth::HtoS => "s.h", // dst.src
        OpWidth::BtoH => "h.b",
    }
}

fn parse_width(s: &str) -> Option<OpWidth> {
    Some(match s {
        "s.h" => OpWidth::HtoS,
        "h.b" => OpWidth::BtoH,
        _ => return None,
    })
}

/// Render one instruction as assembly text.
pub fn disassemble(i: &Instr) -> String {
    use Instr::*;
    let x = |r: Reg| format!("x{}", r.0);
    let f = |r: FReg| format!("f{}", r.0);
    match *i {
        Lui { rd, imm } => format!("lui {}, {:#x}", x(rd), imm),
        Addi { rd, rs1, imm } => format!("addi {}, {}, {}", x(rd), x(rs1), imm),
        Add { rd, rs1, rs2 } => format!("add {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Sub { rd, rs1, rs2 } => format!("sub {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Mul { rd, rs1, rs2 } => format!("mul {}, {}, {}", x(rd), x(rs1), x(rs2)),
        Slli { rd, rs1, shamt } => format!("slli {}, {}, {}", x(rd), x(rs1), shamt),
        Srli { rd, rs1, shamt } => format!("srli {}, {}, {}", x(rd), x(rs1), shamt),
        Beq { rs1, rs2, offset } => format!("beq {}, {}, {}", x(rs1), x(rs2), offset),
        Bne { rs1, rs2, offset } => format!("bne {}, {}, {}", x(rs1), x(rs2), offset),
        Blt { rs1, rs2, offset } => format!("blt {}, {}, {}", x(rs1), x(rs2), offset),
        Bge { rs1, rs2, offset } => format!("bge {}, {}, {}", x(rs1), x(rs2), offset),
        Jal { rd, offset } => format!("jal {}, {}", x(rd), offset),
        Lw { rd, rs1, imm } => format!("lw {}, {}({})", x(rd), imm, x(rs1)),
        Sw { rs1, rs2, imm } => format!("sw {}, {}({})", x(rs2), imm, x(rs1)),
        FLoad { fmt, fd, rs1, imm } => format!("fl{} {}, {}({})", ls_suffix(fmt), f(fd), imm, x(rs1)),
        FStore { fmt, rs1, fs, imm } => format!("fs{} {}, {}({})", ls_suffix(fmt), f(fs), imm, x(rs1)),
        Fmadd { fmt, fd, fs1, fs2, fs3 } => {
            format!("fmadd.{} {}, {}, {}, {}", fmt_suffix(fmt), f(fd), f(fs1), f(fs2), f(fs3))
        }
        Fadd { fmt, fd, fs1, fs2 } => format!("fadd.{} {}, {}, {}", fmt_suffix(fmt), f(fd), f(fs1), f(fs2)),
        Fmul { fmt, fd, fs1, fs2 } => format!("fmul.{} {}, {}, {}", fmt_suffix(fmt), f(fd), f(fs1), f(fs2)),
        Fsgnj { fmt, fd, fs1, fs2 } => format!("fsgnj.{} {}, {}, {}", fmt_suffix(fmt), f(fd), f(fs1), f(fs2)),
        Fcvt { to, from, fd, fs1 } => {
            format!("fcvt.{}.{} {}, {}", fmt_suffix(to), fmt_suffix(from), f(fd), f(fs1))
        }
        FmvXW { rd, fs1 } => format!("fmv.x.w {}, {}", x(rd), f(fs1)),
        FmvWX { fd, rs1 } => format!("fmv.w.x {}, {}", f(fd), x(rs1)),
        ExSdotp { w, fd, fs1, fs2 } => format!("exsdotp.{} {}, {}, {}", width_suffix(w), f(fd), f(fs1), f(fs2)),
        ExVsum { w, fd, fs1 } => format!("exvsum.{} {}, {}", width_suffix(w), f(fd), f(fs1)),
        Vsum { w, fd, fs1 } => format!("vsum.{} {}, {}", width_suffix(w), f(fd), f(fs1)),
        Csrrwi { rd, csr, imm } => format!("csrrwi {}, {:#x}, {}", x(rd), csr, imm),
        Csrrw { rd, csr, rs1 } => format!("csrrw {}, {:#x}, {}", x(rd), csr, x(rs1)),
        Csrrs { rd, csr, rs1 } => format!("csrrs {}, {:#x}, {}", x(rd), csr, x(rs1)),
        ScfgWi { rs1, cfg } => format!("scfgwi {}, {}", x(rs1), cfg),
        FrepO { rep, n_inst } => format!("frep.o {}, {}", x(rep), n_inst),
        FrepI { rep, n_inst } => format!("frep.i {}, {}", x(rep), n_inst),
        DmSrc { rs1 } => format!("dmsrc {}", x(rs1)),
        DmDst { rs1 } => format!("dmdst {}", x(rs1)),
        DmCpy { rd, rs1 } => format!("dmcpyi {}, {}", x(rd), x(rs1)),
        DmStat { rd } => format!("dmstati {}", x(rd)),
        Barrier => "barrier".to_string(),
        Halt => "halt".to_string(),
    }
}

/// Render a whole program with line numbers (kernel dumps).
pub fn disassemble_program(prog: &[Instr]) -> String {
    prog.iter().enumerate().map(|(n, i)| format!("{n:4}: {}\n", disassemble(i))).collect()
}

fn parse_xreg(s: &str) -> Option<Reg> {
    let t = s.trim().trim_end_matches(',');
    t.strip_prefix('x')?.parse::<u8>().ok().filter(|&n| n < 32).map(Reg)
}

fn parse_freg(s: &str) -> Option<FReg> {
    let t = s.trim().trim_end_matches(',');
    t.strip_prefix('f')?.parse::<u8>().ok().filter(|&n| n < 32).map(FReg)
}

fn parse_imm(s: &str) -> Option<i32> {
    let t = s.trim().trim_end_matches(',');
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("-0x")) {
        let v = i64::from_str_radix(hex, 16).ok()?;
        Some(if t.starts_with('-') { -(v as i32) } else { v as i32 })
    } else {
        t.parse().ok()
    }
}

/// Parse `imm(xN)` memory operands.
fn parse_mem(s: &str) -> Option<(i32, Reg)> {
    let t = s.trim().trim_end_matches(',');
    let open = t.find('(')?;
    let imm = parse_imm(&t[..open])?;
    let reg = parse_xreg(t[open + 1..].trim_end_matches(')'))?;
    Some((imm, reg))
}

/// Assemble one line. Comments (`#`) and empty lines yield `None`.
pub fn assemble_line(line: &str) -> Option<Instr> {
    use Instr::*;
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return None;
    }
    let mut parts = line.split_whitespace();
    let mnemonic = parts.next()?;
    let ops: Vec<&str> = parts.collect();
    let (base, suffix) = match mnemonic.split_once('.') {
        Some((b, s)) => (b, s),
        None => (mnemonic, ""),
    };
    Some(match (base, suffix) {
        ("lui", _) => Lui { rd: parse_xreg(ops[0])?, imm: parse_imm(ops[1])? },
        ("addi", _) => Addi { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, imm: parse_imm(ops[2])? },
        ("add", _) => Add { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, rs2: parse_xreg(ops[2])? },
        ("sub", _) => Sub { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, rs2: parse_xreg(ops[2])? },
        ("mul", _) => Mul { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, rs2: parse_xreg(ops[2])? },
        ("slli", _) => Slli { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, shamt: parse_imm(ops[2])? as u8 },
        ("srli", _) => Srli { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])?, shamt: parse_imm(ops[2])? as u8 },
        ("beq", _) => Beq { rs1: parse_xreg(ops[0])?, rs2: parse_xreg(ops[1])?, offset: parse_imm(ops[2])? },
        ("bne", _) => Bne { rs1: parse_xreg(ops[0])?, rs2: parse_xreg(ops[1])?, offset: parse_imm(ops[2])? },
        ("blt", _) => Blt { rs1: parse_xreg(ops[0])?, rs2: parse_xreg(ops[1])?, offset: parse_imm(ops[2])? },
        ("bge", _) => Bge { rs1: parse_xreg(ops[0])?, rs2: parse_xreg(ops[1])?, offset: parse_imm(ops[2])? },
        ("jal", _) => Jal { rd: parse_xreg(ops[0])?, offset: parse_imm(ops[1])? },
        ("lw", _) => {
            let (imm, rs1) = parse_mem(ops[1])?;
            Lw { rd: parse_xreg(ops[0])?, rs1, imm }
        }
        ("sw", _) => {
            let (imm, rs1) = parse_mem(ops[1])?;
            Sw { rs1, rs2: parse_xreg(ops[0])?, imm }
        }
        ("fld", _) | ("flw", _) | ("flh", _) | ("flb", _) => {
            let (imm, rs1) = parse_mem(ops[1])?;
            FLoad { fmt: parse_ls(&base[2..3])?, fd: parse_freg(ops[0])?, rs1, imm }
        }
        ("fsd", _) | ("fsw", _) | ("fsh", _) | ("fsb", _) => {
            let (imm, rs1) = parse_mem(ops[1])?;
            FStore { fmt: parse_ls(&base[2..3])?, rs1, fs: parse_freg(ops[0])?, imm }
        }
        ("fmadd", s) => Fmadd {
            fmt: parse_fmt(s)?,
            fd: parse_freg(ops[0])?,
            fs1: parse_freg(ops[1])?,
            fs2: parse_freg(ops[2])?,
            fs3: parse_freg(ops[3])?,
        },
        ("fadd", s) => {
            Fadd { fmt: parse_fmt(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])?, fs2: parse_freg(ops[2])? }
        }
        ("fmul", s) => {
            Fmul { fmt: parse_fmt(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])?, fs2: parse_freg(ops[2])? }
        }
        ("fsgnj", s) => {
            Fsgnj { fmt: parse_fmt(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])?, fs2: parse_freg(ops[2])? }
        }
        ("fcvt", s) => {
            let (to, from) = s.split_once('.')?;
            Fcvt { to: parse_fmt(to)?, from: parse_fmt(from)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])? }
        }
        ("fmv", "x.w") => FmvXW { rd: parse_xreg(ops[0])?, fs1: parse_freg(ops[1])? },
        ("fmv", "w.x") => FmvWX { fd: parse_freg(ops[0])?, rs1: parse_xreg(ops[1])? },
        ("exsdotp", s) => {
            ExSdotp { w: parse_width(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])?, fs2: parse_freg(ops[2])? }
        }
        ("exvsum", s) => ExVsum { w: parse_width(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])? },
        ("vsum", s) => Vsum { w: parse_width(s)?, fd: parse_freg(ops[0])?, fs1: parse_freg(ops[1])? },
        ("csrrwi", _) => {
            Csrrwi { rd: parse_xreg(ops[0])?, csr: parse_imm(ops[1])? as u16, imm: parse_imm(ops[2])? as u8 }
        }
        ("csrrw", _) => Csrrw { rd: parse_xreg(ops[0])?, csr: parse_imm(ops[1])? as u16, rs1: parse_xreg(ops[2])? },
        ("csrrs", _) => Csrrs { rd: parse_xreg(ops[0])?, csr: parse_imm(ops[1])? as u16, rs1: parse_xreg(ops[2])? },
        ("scfgwi", _) => ScfgWi { rs1: parse_xreg(ops[0])?, cfg: parse_imm(ops[1])? as u16 },
        ("frep", "o") => FrepO { rep: parse_xreg(ops[0])?, n_inst: parse_imm(ops[1])? as u8 },
        ("frep", "i") => FrepI { rep: parse_xreg(ops[0])?, n_inst: parse_imm(ops[1])? as u8 },
        ("dmsrc", _) => DmSrc { rs1: parse_xreg(ops[0])? },
        ("dmdst", _) => DmDst { rs1: parse_xreg(ops[0])? },
        ("dmcpyi", _) => DmCpy { rd: parse_xreg(ops[0])?, rs1: parse_xreg(ops[1])? },
        ("dmstati", _) => DmStat { rd: parse_xreg(ops[0])? },
        ("barrier", _) => Barrier,
        ("halt", _) => Halt,
        _ => return None,
    })
}

/// Assemble a multi-line program.
pub fn assemble(src: &str) -> Vec<Instr> {
    src.lines().filter_map(assemble_line).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::regs::*;

    #[test]
    fn disasm_asm_roundtrip() {
        use Instr::*;
        let prog = vec![
            Lui { rd: x(5), imm: 0x12345 },
            Addi { rd: x(5), rs1: x(6), imm: -7 },
            Fmadd { fmt: ScalarFmt::H, fd: f(4), fs1: FT0, fs2: FT1, fs3: f(4) },
            ExSdotp { w: OpWidth::HtoS, fd: f(3), fs1: FT0, fs2: FT1 },
            ExVsum { w: OpWidth::BtoH, fd: f(3), fs1: f(4) },
            Vsum { w: OpWidth::HtoS, fd: f(3), fs1: f(4) },
            Fcvt { to: ScalarFmt::S, from: ScalarFmt::H, fd: f(3), fs1: f(4) },
            FrepO { rep: x(20), n_inst: 4 },
            ScfgWi { rs1: x(5), cfg: 737 },
            Lw { rd: x(7), rs1: x(2), imm: 16 },
            FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(9), imm: -8 },
            FStore { fmt: ScalarFmt::H, rs1: x(10), fs: f(9), imm: 6 },
            FLoad { fmt: ScalarFmt::B, fd: f(9), rs1: x(10), imm: 3 },
            Csrrwi { rd: ZERO, csr: 3, imm: 1 },
            Barrier,
            Halt,
        ];
        for i in &prog {
            let text = disassemble(i);
            let back = assemble_line(&text).unwrap_or_else(|| panic!("parse failed: '{text}'"));
            assert_eq!(&back, i, "text was '{text}'");
        }
    }

    #[test]
    fn assemble_program_with_comments() {
        let src = "
            # zero out x5
            addi x5, x0, 0
            addi x6, x0, 64    # loop bound
            fmadd.d f4, f1, f2, f4
            bne x5, x6, -1
            halt
        ";
        let prog = assemble(src);
        assert_eq!(prog.len(), 5);
        assert!(matches!(prog[2], Instr::Fmadd { fmt: ScalarFmt::D, .. }));
        assert!(matches!(prog[4], Instr::Halt));
    }

    #[test]
    fn disassemble_program_numbers_lines() {
        let p = vec![Instr::Halt, Instr::Barrier];
        let text = disassemble_program(&p);
        assert!(text.contains("0: halt"));
        assert!(text.contains("1: barrier"));
    }
}
