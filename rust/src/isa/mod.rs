//! The MiniFloat-NN RISC-V ISA extension (§III-E) plus the subset of
//! RV32I/M, F/D, and the Snitch custom extensions (SSR, FREP, DMA) that
//! the evaluation kernels need.
//!
//! The paper's extension adds three SIMD instructions on top of the
//! smallFloat extension:
//!
//! ```text
//! exsdotp rd, rs1, rs2   # rd_i += rs1_{2i}·rs2_{2i} + rs1_{2i+1}·rs2_{2i+1}
//! exvsum  rd, rs1        # rd_i += rs1_{2i} + rs1_{2i+1}   (expanding)
//! vsum    rd, rs1        # rd_i  = rs1_{2i} + rs1_{2i+1} + rd_i
//! ```
//!
//! `rd` doubles as the accumulator input (rs3), packed in the wider
//! destination format. Because encoding space is scarce, the
//! *alternative* formats (FP16alt, FP8alt) are not separate opcodes:
//! two bits in the FP CSR — `src_is_alt` and `dst_is_alt` — retarget the
//! same instruction, so "an FP16alt kernel differs from an FP16 kernel
//! by a single CSR write" (§III-E). [`csr::FpCsr`] models this.
//!
//! * [`instr`] — the instruction forms as a typed enum.
//! * [`encode`] — 32-bit instruction encodings (R/I/S/B/U/J/R4 plus the
//!   custom-opcode encodings for the extension) with a full
//!   encode/decode round-trip.
//! * [`asm`] — a small assembler/disassembler for writing kernels and
//!   debugging traces.
//! * [`csr`] — the FP CSR with `frm`, `src_is_alt`, `dst_is_alt`.

pub mod asm;
pub mod csr;
pub mod encode;
pub mod instr;

pub use csr::FpCsr;
pub use instr::{FReg, Instr, OpWidth, Reg, ScalarFmt};
