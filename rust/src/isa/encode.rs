//! 32-bit binary encodings.
//!
//! Standard RISC-V formats (R/I/S/B/U/J, R4 for FMA, OP-FP) for the base
//! ISA, and the custom opcodes Snitch and MiniFloat-NN claim:
//!
//! * `custom-1` (0x2b): the MiniFloat-NN extension. R-type; `funct3`
//!   selects the operation (0 = exsdotp, 1 = exvsum, 2 = vsum) and
//!   `funct7[0]` the width pair (0 = 16→32, 1 = 8→16). `rd` is both
//!   accumulator source and destination, exactly as in §III-E.
//! * `custom-0` (0x0b): `scfgwi` (SSR config write).
//! * `custom-2` (0x5b): `frep.o` / `frep.i`, barrier, halt.
//! * `custom-3` (0x7b): the DMA core's `dmsrc/dmdst/dmcpyi/dmstati`.
//!
//! Branch/jump offsets are kept in *instruction* units by the simulator
//! and scaled by 4 in the encoding, so the encoded form is exactly what
//! a real binary would hold.

use super::instr::{FReg, Instr, OpWidth, Reg, ScalarFmt};

const OP_LUI: u32 = 0x37;
const OP_IMM: u32 = 0x13;
const OP_REG: u32 = 0x33;
const OP_BRANCH: u32 = 0x63;
const OP_JAL: u32 = 0x6f;
const OP_LOAD: u32 = 0x03;
const OP_STORE: u32 = 0x23;
const OP_LOAD_FP: u32 = 0x07;
const OP_STORE_FP: u32 = 0x27;
const OP_FMADD: u32 = 0x43;
const OP_FP: u32 = 0x53;
const OP_SYSTEM: u32 = 0x73;
const OP_CUSTOM0: u32 = 0x0b; // scfgwi
const OP_CUSTOM1: u32 = 0x2b; // minifloat-nn
const OP_CUSTOM2: u32 = 0x5b; // frep / barrier / halt
const OP_CUSTOM3: u32 = 0x7b; // dma

/// Load/store funct3 per the RISC-V F/D/Zfh convention (flb=0, flh=1,
/// flw=2, fld=3).
fn ls_f3(f: ScalarFmt) -> u32 {
    match f {
        ScalarFmt::B => 0,
        ScalarFmt::H => 1,
        ScalarFmt::S => 2,
        ScalarFmt::D => 3,
    }
}

fn f3_ls(b: u32) -> Option<ScalarFmt> {
    Some(match b {
        0 => ScalarFmt::B,
        1 => ScalarFmt::H,
        2 => ScalarFmt::S,
        3 => ScalarFmt::D,
        _ => return None,
    })
}

fn fmt_bits(f: ScalarFmt) -> u32 {
    match f {
        ScalarFmt::S => 0b00,
        ScalarFmt::D => 0b01,
        ScalarFmt::H => 0b10,
        ScalarFmt::B => 0b11,
    }
}

fn bits_fmt(b: u32) -> ScalarFmt {
    match b & 0b11 {
        0b00 => ScalarFmt::S,
        0b01 => ScalarFmt::D,
        0b10 => ScalarFmt::H,
        _ => ScalarFmt::B,
    }
}

fn r_type(op: u32, rd: u32, f3: u32, rs1: u32, rs2: u32, f7: u32) -> u32 {
    op | (rd << 7) | (f3 << 12) | (rs1 << 15) | (rs2 << 20) | (f7 << 25)
}

fn i_type(op: u32, rd: u32, f3: u32, rs1: u32, imm: i32) -> u32 {
    op | (rd << 7) | (f3 << 12) | (rs1 << 15) | (((imm as u32) & 0xfff) << 20)
}

fn s_type(op: u32, f3: u32, rs1: u32, rs2: u32, imm: i32) -> u32 {
    let imm = imm as u32;
    op | ((imm & 0x1f) << 7) | (f3 << 12) | (rs1 << 15) | (rs2 << 20) | (((imm >> 5) & 0x7f) << 25)
}

fn b_type(op: u32, f3: u32, rs1: u32, rs2: u32, imm: i32) -> u32 {
    let imm = imm as u32; // byte offset, imm[0] implicitly 0
    op | (((imm >> 11) & 1) << 7)
        | (((imm >> 1) & 0xf) << 8)
        | (f3 << 12)
        | (rs1 << 15)
        | (rs2 << 20)
        | (((imm >> 5) & 0x3f) << 25)
        | (((imm >> 12) & 1) << 31)
}

fn u_type(op: u32, rd: u32, imm: i32) -> u32 {
    op | (rd << 7) | ((imm as u32) << 12)
}

fn j_type(op: u32, rd: u32, imm: i32) -> u32 {
    let imm = imm as u32;
    op | (rd << 7)
        | (((imm >> 12) & 0xff) << 12)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 1) & 0x3ff) << 21)
        | (((imm >> 20) & 1) << 31)
}

fn i_imm(w: u32) -> i32 {
    ((w as i32) >> 20) as i32
}

fn s_imm(w: u32) -> i32 {
    let lo = (w >> 7) & 0x1f;
    let hi = (w >> 25) & 0x7f;
    (((hi << 5) | lo) as i32) << 20 >> 20
}

fn b_imm(w: u32) -> i32 {
    let imm = (((w >> 31) & 1) << 12) | (((w >> 7) & 1) << 11) | (((w >> 25) & 0x3f) << 5) | (((w >> 8) & 0xf) << 1);
    ((imm as i32) << 19) >> 19
}

fn j_imm(w: u32) -> i32 {
    let imm =
        (((w >> 31) & 1) << 20) | (((w >> 12) & 0xff) << 12) | (((w >> 20) & 1) << 11) | (((w >> 21) & 0x3ff) << 1);
    ((imm as i32) << 11) >> 11
}

fn rd(w: u32) -> u32 {
    (w >> 7) & 0x1f
}

fn f3(w: u32) -> u32 {
    (w >> 12) & 0x7
}

fn rs1(w: u32) -> u32 {
    (w >> 15) & 0x1f
}

fn rs2(w: u32) -> u32 {
    (w >> 20) & 0x1f
}

fn f7(w: u32) -> u32 {
    (w >> 25) & 0x7f
}

/// Encode an instruction to its 32-bit form.
pub fn encode(i: &Instr) -> u32 {
    use Instr::*;
    match *i {
        Lui { rd: r, imm } => u_type(OP_LUI, r.0 as u32, imm),
        Addi { rd: r, rs1: a, imm } => i_type(OP_IMM, r.0 as u32, 0, a.0 as u32, imm),
        Slli { rd: r, rs1: a, shamt } => i_type(OP_IMM, r.0 as u32, 1, a.0 as u32, shamt as i32),
        Srli { rd: r, rs1: a, shamt } => i_type(OP_IMM, r.0 as u32, 5, a.0 as u32, shamt as i32),
        Add { rd: r, rs1: a, rs2: b } => r_type(OP_REG, r.0 as u32, 0, a.0 as u32, b.0 as u32, 0),
        Sub { rd: r, rs1: a, rs2: b } => r_type(OP_REG, r.0 as u32, 0, a.0 as u32, b.0 as u32, 0x20),
        Mul { rd: r, rs1: a, rs2: b } => r_type(OP_REG, r.0 as u32, 0, a.0 as u32, b.0 as u32, 1),
        Beq { rs1: a, rs2: b, offset } => b_type(OP_BRANCH, 0, a.0 as u32, b.0 as u32, offset * 4),
        Bne { rs1: a, rs2: b, offset } => b_type(OP_BRANCH, 1, a.0 as u32, b.0 as u32, offset * 4),
        Blt { rs1: a, rs2: b, offset } => b_type(OP_BRANCH, 4, a.0 as u32, b.0 as u32, offset * 4),
        Bge { rs1: a, rs2: b, offset } => b_type(OP_BRANCH, 5, a.0 as u32, b.0 as u32, offset * 4),
        Jal { rd: r, offset } => j_type(OP_JAL, r.0 as u32, offset * 4),
        Lw { rd: r, rs1: a, imm } => i_type(OP_LOAD, r.0 as u32, 2, a.0 as u32, imm),
        Sw { rs1: a, rs2: b, imm } => s_type(OP_STORE, 2, a.0 as u32, b.0 as u32, imm),
        FLoad { fmt, fd, rs1: a, imm } => i_type(OP_LOAD_FP, fd.0 as u32, ls_f3(fmt), a.0 as u32, imm),
        FStore { fmt, rs1: a, fs, imm } => s_type(OP_STORE_FP, ls_f3(fmt), a.0 as u32, fs.0 as u32, imm),
        Fmadd { fmt, fd, fs1, fs2, fs3 } => {
            OP_FMADD
                | ((fd.0 as u32) << 7)
                | (fmt_bits(fmt) << 25)
                | ((fs1.0 as u32) << 15)
                | ((fs2.0 as u32) << 20)
                | ((fs3.0 as u32) << 27)
        }
        Fadd { fmt, fd, fs1, fs2 } => {
            r_type(OP_FP, fd.0 as u32, 0, fs1.0 as u32, fs2.0 as u32, fmt_bits(fmt))
        }
        Fmul { fmt, fd, fs1, fs2 } => {
            r_type(OP_FP, fd.0 as u32, 0, fs1.0 as u32, fs2.0 as u32, 0b0001000 | fmt_bits(fmt))
        }
        Fsgnj { fmt, fd, fs1, fs2 } => {
            r_type(OP_FP, fd.0 as u32, 0, fs1.0 as u32, fs2.0 as u32, 0b0010000 | fmt_bits(fmt))
        }
        Fcvt { to, from, fd, fs1 } => {
            // rs2 field carries the source format.
            r_type(OP_FP, fd.0 as u32, 0, fs1.0 as u32, fmt_bits(from), 0b0100000 | fmt_bits(to))
        }
        FmvXW { rd: r, fs1 } => r_type(OP_FP, r.0 as u32, 0, fs1.0 as u32, 0, 0b1110000),
        FmvWX { fd, rs1: a } => r_type(OP_FP, fd.0 as u32, 0, a.0 as u32, 0, 0b1111000),
        ExSdotp { w, fd, fs1, fs2 } => {
            r_type(OP_CUSTOM1, fd.0 as u32, 0, fs1.0 as u32, fs2.0 as u32, (w == OpWidth::BtoH) as u32)
        }
        ExVsum { w, fd, fs1 } => {
            r_type(OP_CUSTOM1, fd.0 as u32, 1, fs1.0 as u32, 0, (w == OpWidth::BtoH) as u32)
        }
        Vsum { w, fd, fs1 } => {
            r_type(OP_CUSTOM1, fd.0 as u32, 2, fs1.0 as u32, 0, (w == OpWidth::BtoH) as u32)
        }
        Csrrwi { rd: r, csr, imm } => i_type(OP_SYSTEM, r.0 as u32, 5, imm as u32, csr as i32),
        Csrrw { rd: r, csr, rs1: a } => i_type(OP_SYSTEM, r.0 as u32, 1, a.0 as u32, csr as i32),
        Csrrs { rd: r, csr, rs1: a } => i_type(OP_SYSTEM, r.0 as u32, 2, a.0 as u32, csr as i32),
        ScfgWi { rs1: a, cfg } => i_type(OP_CUSTOM0, 0, 2, a.0 as u32, cfg as i32),
        FrepO { rep, n_inst } => i_type(OP_CUSTOM2, 0, 0, rep.0 as u32, n_inst as i32),
        FrepI { rep, n_inst } => i_type(OP_CUSTOM2, 0, 1, rep.0 as u32, n_inst as i32),
        Barrier => i_type(OP_CUSTOM2, 0, 7, 0, 0),
        Halt => i_type(OP_CUSTOM2, 0, 6, 0, 0),
        DmSrc { rs1: a } => i_type(OP_CUSTOM3, 0, 0, a.0 as u32, 0),
        DmDst { rs1: a } => i_type(OP_CUSTOM3, 0, 1, a.0 as u32, 0),
        DmCpy { rd: r, rs1: a } => i_type(OP_CUSTOM3, r.0 as u32, 2, a.0 as u32, 0),
        DmStat { rd: r } => i_type(OP_CUSTOM3, r.0 as u32, 3, 0, 0),
    }
}

/// Decode a 32-bit word back to an instruction. `None` for encodings we
/// don't model.
pub fn decode(w: u32) -> Option<Instr> {
    use Instr::*;
    let op = w & 0x7f;
    Some(match op {
        OP_LUI => Lui { rd: Reg(rd(w) as u8), imm: (w >> 12) as i32 },
        OP_IMM => match f3(w) {
            0 => Addi { rd: Reg(rd(w) as u8), rs1: Reg(rs1(w) as u8), imm: i_imm(w) },
            1 => Slli { rd: Reg(rd(w) as u8), rs1: Reg(rs1(w) as u8), shamt: rs2(w) as u8 },
            5 => Srli { rd: Reg(rd(w) as u8), rs1: Reg(rs1(w) as u8), shamt: rs2(w) as u8 },
            _ => return None,
        },
        OP_REG => {
            let (r, a, b) = (Reg(rd(w) as u8), Reg(rs1(w) as u8), Reg(rs2(w) as u8));
            match f7(w) {
                0 => Add { rd: r, rs1: a, rs2: b },
                0x20 => Sub { rd: r, rs1: a, rs2: b },
                1 => Mul { rd: r, rs1: a, rs2: b },
                _ => return None,
            }
        }
        OP_BRANCH => {
            let (a, b, off) = (Reg(rs1(w) as u8), Reg(rs2(w) as u8), b_imm(w) / 4);
            match f3(w) {
                0 => Beq { rs1: a, rs2: b, offset: off },
                1 => Bne { rs1: a, rs2: b, offset: off },
                4 => Blt { rs1: a, rs2: b, offset: off },
                5 => Bge { rs1: a, rs2: b, offset: off },
                _ => return None,
            }
        }
        OP_JAL => Jal { rd: Reg(rd(w) as u8), offset: j_imm(w) / 4 },
        OP_LOAD => match f3(w) {
            2 => Lw { rd: Reg(rd(w) as u8), rs1: Reg(rs1(w) as u8), imm: i_imm(w) },
            _ => return None,
        },
        OP_STORE => match f3(w) {
            2 => Sw { rs1: Reg(rs1(w) as u8), rs2: Reg(rs2(w) as u8), imm: s_imm(w) },
            _ => return None,
        },
        OP_LOAD_FP => {
            FLoad { fmt: f3_ls(f3(w))?, fd: FReg(rd(w) as u8), rs1: Reg(rs1(w) as u8), imm: i_imm(w) }
        }
        OP_STORE_FP => {
            FStore { fmt: f3_ls(f3(w))?, rs1: Reg(rs1(w) as u8), fs: FReg(rs2(w) as u8), imm: s_imm(w) }
        }
        OP_FMADD => Fmadd {
            fmt: bits_fmt((w >> 25) & 0b11),
            fd: FReg(rd(w) as u8),
            fs1: FReg(rs1(w) as u8),
            fs2: FReg(rs2(w) as u8),
            fs3: FReg(((w >> 27) & 0x1f) as u8),
        },
        OP_FP => {
            let fd = FReg(rd(w) as u8);
            let a = FReg(rs1(w) as u8);
            let b = FReg(rs2(w) as u8);
            let f = f7(w);
            match f >> 2 {
                0b00000 => Fadd { fmt: bits_fmt(f), fd, fs1: a, fs2: b },
                0b00010 => Fmul { fmt: bits_fmt(f), fd, fs1: a, fs2: b },
                0b00100 => Fsgnj { fmt: bits_fmt(f), fd, fs1: a, fs2: b },
                0b01000 => Fcvt { to: bits_fmt(f), from: bits_fmt(rs2(w)), fd, fs1: a },
                0b11100 => FmvXW { rd: Reg(rd(w) as u8), fs1: a },
                0b11110 => FmvWX { fd, rs1: Reg(rs1(w) as u8) },
                _ => return None,
            }
        }
        OP_CUSTOM1 => {
            let wdt = if f7(w) & 1 == 1 { OpWidth::BtoH } else { OpWidth::HtoS };
            let fd = FReg(rd(w) as u8);
            let a = FReg(rs1(w) as u8);
            match f3(w) {
                0 => ExSdotp { w: wdt, fd, fs1: a, fs2: FReg(rs2(w) as u8) },
                1 => ExVsum { w: wdt, fd, fs1: a },
                2 => Vsum { w: wdt, fd, fs1: a },
                _ => return None,
            }
        }
        OP_SYSTEM => {
            let csr = ((w >> 20) & 0xfff) as u16;
            match f3(w) {
                1 => Csrrw { rd: Reg(rd(w) as u8), csr, rs1: Reg(rs1(w) as u8) },
                2 => Csrrs { rd: Reg(rd(w) as u8), csr, rs1: Reg(rs1(w) as u8) },
                5 => Csrrwi { rd: Reg(rd(w) as u8), csr, imm: rs1(w) as u8 },
                _ => return None,
            }
        }
        OP_CUSTOM0 => match f3(w) {
            2 => ScfgWi { rs1: Reg(rs1(w) as u8), cfg: (i_imm(w) & 0xfff) as u16 },
            _ => return None,
        },
        OP_CUSTOM2 => match f3(w) {
            0 => FrepO { rep: Reg(rs1(w) as u8), n_inst: (i_imm(w) & 0xff) as u8 },
            1 => FrepI { rep: Reg(rs1(w) as u8), n_inst: (i_imm(w) & 0xff) as u8 },
            6 => Halt,
            7 => Barrier,
            _ => return None,
        },
        OP_CUSTOM3 => match f3(w) {
            0 => DmSrc { rs1: Reg(rs1(w) as u8) },
            1 => DmDst { rs1: Reg(rs1(w) as u8) },
            2 => DmCpy { rd: Reg(rd(w) as u8), rs1: Reg(rs1(w) as u8) },
            3 => DmStat { rd: Reg(rd(w) as u8) },
            _ => return None,
        },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::regs::*;

    fn all_sample_instrs() -> Vec<Instr> {
        use Instr::*;
        vec![
            Lui { rd: x(5), imm: 0x12345 },
            Addi { rd: x(5), rs1: x(6), imm: -7 },
            Addi { rd: x(1), rs1: ZERO, imm: 2047 },
            Add { rd: x(3), rs1: x(4), rs2: x(5) },
            Sub { rd: x(3), rs1: x(4), rs2: x(5) },
            Mul { rd: x(31), rs1: x(30), rs2: x(29) },
            Slli { rd: x(2), rs1: x(2), shamt: 3 },
            Srli { rd: x(2), rs1: x(2), shamt: 31 },
            Beq { rs1: x(1), rs2: x(2), offset: -12 },
            Bne { rs1: x(1), rs2: ZERO, offset: 100 },
            Blt { rs1: x(8), rs2: x(9), offset: 1 },
            Bge { rs1: x(8), rs2: x(9), offset: -1 },
            Jal { rd: ZERO, offset: -200 },
            Lw { rd: x(7), rs1: x(2), imm: 16 },
            Sw { rs1: x(2), rs2: x(7), imm: -16 },
            FLoad { fmt: ScalarFmt::D, fd: f(9), rs1: x(10), imm: 8 },
            FLoad { fmt: ScalarFmt::H, fd: f(9), rs1: x(10), imm: 2 },
            FStore { fmt: ScalarFmt::D, rs1: x(10), fs: f(9), imm: -8 },
            FStore { fmt: ScalarFmt::B, rs1: x(10), fs: f(9), imm: 1 },
            Fmadd { fmt: ScalarFmt::D, fd: f(4), fs1: f(5), fs2: f(6), fs3: f(7) },
            Fmadd { fmt: ScalarFmt::H, fd: FT0, fs1: FT1, fs2: f(3), fs3: f(3) },
            Fadd { fmt: ScalarFmt::S, fd: f(1), fs1: f(2), fs2: f(3) },
            Fmul { fmt: ScalarFmt::B, fd: f(1), fs1: f(2), fs2: f(3) },
            Fsgnj { fmt: ScalarFmt::D, fd: f(11), fs1: f(12), fs2: f(12) },
            Fcvt { to: ScalarFmt::S, from: ScalarFmt::H, fd: f(3), fs1: f(4) },
            FmvXW { rd: x(13), fs1: f(14) },
            FmvWX { fd: f(14), rs1: x(13) },
            ExSdotp { w: OpWidth::HtoS, fd: f(3), fs1: FT0, fs2: FT1 },
            ExSdotp { w: OpWidth::BtoH, fd: f(17), fs1: f(18), fs2: f(19) },
            ExVsum { w: OpWidth::HtoS, fd: f(3), fs1: f(4) },
            Vsum { w: OpWidth::BtoH, fd: f(3), fs1: f(4) },
            Csrrwi { rd: ZERO, csr: 0x003, imm: 1 },
            Csrrw { rd: x(1), csr: 0x7c0, rs1: x(2) },
            Csrrs { rd: x(1), csr: 0xf14, rs1: ZERO },
            ScfgWi { rs1: x(5), cfg: 0x2e1 },
            FrepO { rep: x(20), n_inst: 4 },
            FrepI { rep: x(20), n_inst: 1 },
            DmSrc { rs1: x(10) },
            DmDst { rs1: x(11) },
            DmCpy { rd: x(12), rs1: x(13) },
            DmStat { rd: x(12) },
            Barrier,
            Halt,
        ]
    }

    #[test]
    fn encode_decode_roundtrip() {
        for i in all_sample_instrs() {
            let w = encode(&i);
            let back = decode(w).unwrap_or_else(|| panic!("decode failed for {i:?} ({w:#010x})"));
            assert_eq!(back, i, "roundtrip mismatch ({w:#010x})");
        }
    }

    #[test]
    fn opcode_fields_are_riscv_shaped() {
        // Spot-check a known encoding: addi x1, x0, 1 == 0x00100093.
        let w = encode(&Instr::Addi { rd: x(1), rs1: ZERO, imm: 1 });
        assert_eq!(w, 0x0010_0093);
        // lui x5, 0x12345 == 0x123452b7.
        let w = encode(&Instr::Lui { rd: x(5), imm: 0x12345 });
        assert_eq!(w, 0x1234_52b7);
        // fld f9, 8(x10) == imm=8, rs1=10, f3=3, rd=9, op=0x07.
        let w = encode(&Instr::FLoad { fmt: ScalarFmt::D, fd: f(9), rs1: x(10), imm: 8 });
        assert_eq!(w, (8 << 20) | (10 << 15) | (3 << 12) | (9 << 7) | 0x07);
    }

    #[test]
    fn branch_offsets_encode_as_byte_offsets() {
        let i = Instr::Bne { rs1: x(1), rs2: ZERO, offset: -3 };
        let w = encode(&i);
        assert_eq!(b_imm(w), -12);
        assert_eq!(decode(w), Some(i));
    }

    #[test]
    fn undecodable_patterns_return_none() {
        assert_eq!(decode(0), None);
        assert_eq!(decode(0xffff_ffff), None);
    }

    #[test]
    fn minifloat_nn_opcode_is_custom1() {
        let w = encode(&Instr::ExSdotp { w: OpWidth::HtoS, fd: f(3), fs1: f(0), fs2: f(1) });
        assert_eq!(w & 0x7f, 0x2b);
        let w8 = encode(&Instr::ExSdotp { w: OpWidth::BtoH, fd: f(3), fs1: f(0), fs2: f(1) });
        assert_eq!((w8 >> 25) & 1, 1); // width bit
    }
}
