//! Typed instruction forms.
//!
//! The simulator executes this enum directly (programs are `Vec<Instr>`
//! — no decode in the hot loop); [`super::encode`] provides the binary
//! encoding layer with a lossless round-trip, which is what an actual
//! binary would store.

/// Integer register `x0..x31` (`x0` hardwired to zero).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Reg(pub u8);

/// FP register `f0..f31` (64-bit entries; `f0..f2` are the SSR-mapped
/// registers `ft0..ft2` when SSRs are enabled).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct FReg(pub u8);

/// Width selector for the MiniFloat-NN SIMD instructions: which pair of
/// (source, destination) widths the instruction operates on. The actual
/// formats are refined by the CSR `src_is_alt` / `dst_is_alt` bits.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpWidth {
    /// 16-bit sources → 32-bit destination (2 lanes).
    HtoS,
    /// 8-bit sources → 16-bit destination (4 lanes).
    BtoH,
}

/// Scalar / vectorial FP format selector for classic F/D/smallFloat ops.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScalarFmt {
    /// FP64 (`.d`)
    D,
    /// FP32 (`.s`)
    S,
    /// FP16 or FP16alt per CSR (`.h`)
    H,
    /// FP8 or FP8alt per CSR (`.b`)
    B,
}

impl ScalarFmt {
    /// Storage width in bits.
    pub const fn width(self) -> u32 {
        match self {
            ScalarFmt::D => 64,
            ScalarFmt::S => 32,
            ScalarFmt::H => 16,
            ScalarFmt::B => 8,
        }
    }

    /// SIMD lanes in a 64-bit register.
    pub const fn lanes(self) -> u32 {
        64 / self.width()
    }
}

/// The instruction set: RV32I/M subset + F/D/smallFloat subset + Snitch
/// SSR/FREP/DMA + the MiniFloat-NN extension.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Instr {
    // ---- RV32I subset -------------------------------------------------
    /// `lui rd, imm20`
    Lui { rd: Reg, imm: i32 },
    /// `addi rd, rs1, imm12`
    Addi { rd: Reg, rs1: Reg, imm: i32 },
    /// `add rd, rs1, rs2`
    Add { rd: Reg, rs1: Reg, rs2: Reg },
    /// `sub rd, rs1, rs2`
    Sub { rd: Reg, rs1: Reg, rs2: Reg },
    /// `slli rd, rs1, shamt`
    Slli { rd: Reg, rs1: Reg, shamt: u8 },
    /// `srli rd, rs1, shamt`
    Srli { rd: Reg, rs1: Reg, shamt: u8 },
    /// `mul rd, rs1, rs2` (M extension; address arithmetic)
    Mul { rd: Reg, rs1: Reg, rs2: Reg },
    /// `beq rs1, rs2, ±offset` (offset in *instructions*, resolved)
    Beq { rs1: Reg, rs2: Reg, offset: i32 },
    /// `bne rs1, rs2, ±offset`
    Bne { rs1: Reg, rs2: Reg, offset: i32 },
    /// `blt rs1, rs2, ±offset`
    Blt { rs1: Reg, rs2: Reg, offset: i32 },
    /// `bge rs1, rs2, ±offset`
    Bge { rs1: Reg, rs2: Reg, offset: i32 },
    /// `jal rd, ±offset`
    Jal { rd: Reg, offset: i32 },
    /// `lw rd, imm(rs1)`
    Lw { rd: Reg, rs1: Reg, imm: i32 },
    /// `sw rs2, imm(rs1)`
    Sw { rs1: Reg, rs2: Reg, imm: i32 },

    // ---- FP loads/stores (fld/flw/flh/flb, fsd/fsw/fsh/fsb) -------------
    /// `fl<sz> fd, imm(rs1)` — FP load of `fmt.width()` bits (zero-extended
    /// into the 64-bit register; packed-SIMD data uses the D width).
    FLoad { fmt: ScalarFmt, fd: FReg, rs1: Reg, imm: i32 },
    /// `fs<sz> fs, imm(rs1)` — FP store of the low `fmt.width()` bits.
    FStore { fmt: ScalarFmt, rs1: Reg, fs: FReg, imm: i32 },

    // ---- scalar / vectorial FP compute ---------------------------------
    /// `fmadd.<fmt> fd, fs1, fs2, fs3` — scalar FMA (D/S) or, for H/B,
    /// packed-SIMD vectorial FMA over all lanes (smallFloat `vfmac`).
    Fmadd { fmt: ScalarFmt, fd: FReg, fs1: FReg, fs2: FReg, fs3: FReg },
    /// `fadd.<fmt> fd, fs1, fs2` (vectorial for H/B)
    Fadd { fmt: ScalarFmt, fd: FReg, fs1: FReg, fs2: FReg },
    /// `fmul.<fmt> fd, fs1, fs2` (vectorial for H/B)
    Fmul { fmt: ScalarFmt, fd: FReg, fs1: FReg, fs2: FReg },
    /// `fsgnj.<fmt> fd, fs1, fs2` (also `fmv`: fsgnj fd, fs, fs)
    Fsgnj { fmt: ScalarFmt, fd: FReg, fs1: FReg, fs2: FReg },
    /// `fcvt.<to>.<from> fd, fs1` — scalar format conversion
    Fcvt { to: ScalarFmt, from: ScalarFmt, fd: FReg, fs1: FReg },
    /// `fmv.x.w rd, fs1` — move low 32 bits of FP reg to int reg
    FmvXW { rd: Reg, fs1: FReg },
    /// `fmv.w.x fd, rs1` — move int reg to low 32 bits of FP reg
    FmvWX { fd: FReg, rs1: Reg },

    // ---- MiniFloat-NN extension (§III-E) --------------------------------
    /// `exsdotp rd, rs1, rs2` — SIMD expanding sum of dot products; `rd`
    /// is also the accumulator.
    ExSdotp { w: OpWidth, fd: FReg, fs1: FReg, fs2: FReg },
    /// `exvsum rd, rs1` — SIMD expanding vector inner sum.
    ExVsum { w: OpWidth, fd: FReg, fs1: FReg },
    /// `vsum rd, rs1` — SIMD non-expanding vector inner sum.
    Vsum { w: OpWidth, fd: FReg, fs1: FReg },

    // ---- CSR ------------------------------------------------------------
    /// `csrrwi rd, csr, imm5` — CSR write-immediate (rounding mode, alt
    /// bits, SSR enable).
    Csrrwi { rd: Reg, csr: u16, imm: u8 },
    /// `csrrw rd, csr, rs1`
    Csrrw { rd: Reg, csr: u16, rs1: Reg },
    /// `csrrs rd, csr, rs1` (set bits; `rs1 = x0` → pure read)
    Csrrs { rd: Reg, csr: u16, rs1: Reg },

    // ---- Snitch SSR / FREP ----------------------------------------------
    /// `scfgwi rs1, ssr*32+reg` — write an SSR config register (value
    /// from `rs1`; dm/register index immediate, like Snitch).
    ScfgWi { rs1: Reg, cfg: u16 },
    /// `frep.o rs1, n_inst` — repeat the next `n_inst` FP instructions
    /// `rs1` times total (outer repetition).
    FrepO { rep: Reg, n_inst: u8 },
    /// `frep.i rs1, n_inst` — inner repetition (each instruction
    /// repeated back-to-back).
    FrepI { rep: Reg, n_inst: u8 },

    // ---- Snitch DMA (the 9th core) ---------------------------------------
    /// `dmsrc rs1` — set DMA source address.
    DmSrc { rs1: Reg },
    /// `dmdst rs1` — set DMA destination address.
    DmDst { rs1: Reg },
    /// `dmcpyi rd, rs1` — start a 1-D copy of `rs1` bytes; `rd` receives
    /// the transfer id.
    DmCpy { rd: Reg, rs1: Reg },
    /// `dmstati rd` — busy-wait handle: `rd` = outstanding transfers.
    DmStat { rd: Reg },

    // ---- synchronization --------------------------------------------------
    /// Cluster hardware barrier (`csrr x0, barrier` on Snitch).
    Barrier,
    /// Stop this hart (custom `wfi`-like halt).
    Halt,
}

impl Instr {
    /// Does this instruction execute on the FP subsystem (issued through
    /// the Snitch accelerator interface / FREP sequencer)?
    pub fn is_fp(&self) -> bool {
        matches!(
            self,
            Instr::Fmadd { .. }
                | Instr::Fadd { .. }
                | Instr::Fmul { .. }
                | Instr::Fsgnj { .. }
                | Instr::Fcvt { .. }
                | Instr::ExSdotp { .. }
                | Instr::ExVsum { .. }
                | Instr::Vsum { .. }
                | Instr::FLoad { .. }
                | Instr::FStore { .. }
        )
    }

    /// FP registers read by this instruction (excluding SSR semantics —
    /// the core decides whether an `f0..f2` read hits a stream).
    /// Allocation-free: returns a fixed array + count (this sits on the
    /// simulator's per-cycle hot path).
    pub fn fp_reads(&self) -> FpReads {
        let mut r = FpReads { regs: [FReg(0); 3], n: 0 };
        match *self {
            Instr::Fmadd { fs1, fs2, fs3, .. } => r.set(&[fs1, fs2, fs3]),
            Instr::Fadd { fs1, fs2, .. } | Instr::Fmul { fs1, fs2, .. } | Instr::Fsgnj { fs1, fs2, .. } => {
                r.set(&[fs1, fs2])
            }
            Instr::Fcvt { fs1, .. } => r.set(&[fs1]),
            Instr::ExSdotp { fs1, fs2, fd, .. } => r.set(&[fs1, fs2, fd]),
            Instr::ExVsum { fs1, fd, .. } | Instr::Vsum { fs1, fd, .. } => r.set(&[fs1, fd]),
            Instr::FStore { fs, .. } => r.set(&[fs]),
            Instr::FmvXW { fs1, .. } => r.set(&[fs1]),
            _ => {}
        }
        r
    }

    /// FP register written by this instruction.
    pub fn fp_write(&self) -> Option<FReg> {
        match *self {
            Instr::Fmadd { fd, .. }
            | Instr::Fadd { fd, .. }
            | Instr::Fmul { fd, .. }
            | Instr::Fsgnj { fd, .. }
            | Instr::Fcvt { fd, .. }
            | Instr::ExSdotp { fd, .. }
            | Instr::ExVsum { fd, .. }
            | Instr::Vsum { fd, .. }
            | Instr::FLoad { fd, .. }
            | Instr::FmvWX { fd, .. } => Some(fd),
            _ => None,
        }
    }
}

/// A small fixed set of FP register reads (max 3), avoiding heap
/// allocation on the issue path.
#[derive(Clone, Copy, Debug)]
pub struct FpReads {
    regs: [FReg; 3],
    n: u8,
}

impl FpReads {
    fn set(&mut self, rs: &[FReg]) {
        self.regs[..rs.len()].copy_from_slice(rs);
        self.n = rs.len() as u8;
    }

    /// Iterate the registers.
    pub fn iter(&self) -> impl Iterator<Item = FReg> + '_ {
        self.regs[..self.n as usize].iter().copied()
    }
}

/// Convenience constructors for the register names used in kernels.
pub mod regs {
    use super::{FReg, Reg};

    /// `x0` — hardwired zero.
    pub const ZERO: Reg = Reg(0);
    /// `x1` — return address / scratch.
    pub const RA: Reg = Reg(1);
    /// `x2` — stack pointer / scratch.
    pub const SP: Reg = Reg(2);

    /// General helper: `x(n)`.
    pub const fn x(n: u8) -> Reg {
        Reg(n)
    }

    /// General helper: `f(n)`.
    pub const fn f(n: u8) -> FReg {
        FReg(n)
    }

    /// SSR-mapped stream registers.
    pub const FT0: FReg = FReg(0);
    /// Stream register 1.
    pub const FT1: FReg = FReg(1);
    /// Stream register 2 (commonly the write stream).
    pub const FT2: FReg = FReg(2);
}
