//! IEEE-754 operations over arbitrary formats.
//!
//! All operations unpack exactly, compute exactly on wide integer
//! significands, and round once via [`round_pack`]. The expanding FMA
//! ([`ex_fma`]) is the paper's ExFMA baseline: sources in a narrow
//! format, addend/result in a wider one, one rounding per FMA — so a
//! *cascade* of two `ex_fma` calls rounds twice, which is exactly the
//! behaviour the fused ExSdotp unit improves on (§II-B, Fig. 3).

use super::round::{round_pack, RoundingMode};
use super::unpack::{unpack, Class, Unpacked};
use crate::formats::FpFormat;
use std::cmp::Ordering;

/// Working normalization point: significand MSB is placed at this bit.
/// 120 leaves room for a 106-bit FP64 product plus guard bits in a u128.
const NORM_BIT: u32 = 120;

/// RISC-V `fclass`-style value classification.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FpClass {
    /// −∞
    NegInf,
    /// Negative normal.
    NegNormal,
    /// Negative subnormal.
    NegSubnormal,
    /// −0
    NegZero,
    /// +0
    PosZero,
    /// Positive subnormal.
    PosSubnormal,
    /// Positive normal.
    PosNormal,
    /// +∞
    PosInf,
    /// Signaling NaN (MSB of mantissa clear).
    SignalingNan,
    /// Quiet NaN.
    QuietNan,
}

/// Classify an encoding (RISC-V `fclass` semantics).
pub fn classify(fmt: FpFormat, bits: u64) -> FpClass {
    let u = unpack(fmt, bits);
    match u.class {
        Class::NaN => {
            let (_, _, man) = fmt.split(bits & fmt.width_mask());
            if man >> (fmt.man_bits - 1) & 1 == 1 {
                FpClass::QuietNan
            } else {
                FpClass::SignalingNan
            }
        }
        Class::Inf => {
            if u.sign {
                FpClass::NegInf
            } else {
                FpClass::PosInf
            }
        }
        Class::Zero => {
            if u.sign {
                FpClass::NegZero
            } else {
                FpClass::PosZero
            }
        }
        Class::Subnormal => {
            if u.sign {
                FpClass::NegSubnormal
            } else {
                FpClass::PosSubnormal
            }
        }
        Class::Normal => {
            if u.sign {
                FpClass::NegNormal
            } else {
                FpClass::PosNormal
            }
        }
    }
}

/// A finite nonzero value normalized so the significand MSB is at
/// [`NORM_BIT`]: `value = (-1)^sign * mant * 2^(e_msb - NORM_BIT)`.
#[derive(Clone, Copy, Debug)]
struct Norm {
    sign: bool,
    e_msb: i32,
    mant: u128,
}

/// Normalize an exact (sign, exp, mant≠0) triple.
#[inline]
fn normalize(sign: bool, exp: i32, mant: u128) -> Norm {
    debug_assert!(mant != 0);
    let msb = 127 - mant.leading_zeros();
    let e_msb = exp + msb as i32;
    let mant = if msb < NORM_BIT { mant << (NORM_BIT - msb) } else { mant >> (msb - NORM_BIT) };
    // The right-shift branch is unreachable for inputs ≤ 120 bits, which
    // covers every caller (products are ≤ 106 bits).
    Norm { sign, e_msb, mant }
}

/// Exact signed addition of two normalized values. Returns
/// `(sign, exp_of_lsb, mant, sticky)` ready for [`round_pack`]; a zero
/// mant with `sticky=false` means an exact zero (sign decided by caller).
#[inline]
fn add_norm(x: Norm, y: Norm) -> (bool, i32, u128, bool) {
    // Order by magnitude.
    let (big, small) = if (x.e_msb, x.mant) >= (y.e_msb, y.mant) { (x, y) } else { (y, x) };
    let shift = (big.e_msb - small.e_msb) as u32;
    let base = big.e_msb - NORM_BIT as i32; // weight of working LSB

    let (small_aligned, sticky) = if shift == 0 {
        (small.mant, false)
    } else if shift > 126 {
        (0u128, true)
    } else {
        (small.mant >> shift, small.mant & ((1u128 << shift) - 1) != 0)
    };

    if big.sign == small.sign {
        // Magnitudes add; sum can carry one bit past NORM_BIT (fits).
        (big.sign, base, big.mant + small_aligned, sticky)
    } else {
        // Magnitudes subtract. `big >= small_aligned` by construction.
        // If sticky, the true small is slightly larger than its aligned
        // truncation, so borrow one working ulp and keep sticky set.
        let diff = big.mant - small_aligned - if sticky { 1 } else { 0 };
        (big.sign, base, diff, sticky)
    }
}

/// IEEE addition `a + b` in `fmt`.
#[inline]
pub fn add(fmt: FpFormat, a: u64, b: u64, rm: RoundingMode) -> u64 {
    let ua = unpack(fmt, a);
    let ub = unpack(fmt, b);
    if ua.is_nan() || ub.is_nan() {
        return fmt.quiet_nan();
    }
    match (ua.is_inf(), ub.is_inf()) {
        (true, true) => {
            return if ua.sign == ub.sign { fmt.infinity(ua.sign) } else { fmt.quiet_nan() };
        }
        (true, false) => return fmt.infinity(ua.sign),
        (false, true) => return fmt.infinity(ub.sign),
        _ => {}
    }
    match (ua.is_zero(), ub.is_zero()) {
        (true, true) => {
            let sign = if ua.sign == ub.sign { ua.sign } else { rm == RoundingMode::Rdn };
            return fmt.zero(sign);
        }
        (true, false) => return b & fmt.width_mask(),
        (false, true) => return a & fmt.width_mask(),
        _ => {}
    }
    let na = normalize(ua.sign, ua.exp, ua.mant);
    let nb = normalize(ub.sign, ub.exp, ub.mant);
    let (sign, exp, mant, sticky) = add_norm(na, nb);
    if mant == 0 && !sticky {
        return fmt.zero(rm == RoundingMode::Rdn);
    }
    round_pack(sign, exp, mant, sticky, fmt, rm)
}

/// IEEE subtraction `a - b` in `fmt`.
pub fn sub(fmt: FpFormat, a: u64, b: u64, rm: RoundingMode) -> u64 {
    let nb = (b ^ fmt.sign_mask()) & fmt.width_mask();
    add(fmt, a, nb, rm)
}

/// IEEE multiplication `a * b` in `fmt`.
#[inline]
pub fn mul(fmt: FpFormat, a: u64, b: u64, rm: RoundingMode) -> u64 {
    ex_mul(fmt, fmt, a, b, rm)
}

/// Expanding multiplication: operands in `src`, result in `dst`.
#[inline]
pub fn ex_mul(src: FpFormat, dst: FpFormat, a: u64, b: u64, rm: RoundingMode) -> u64 {
    let ua = unpack(src, a);
    let ub = unpack(src, b);
    if ua.is_nan() || ub.is_nan() {
        return dst.quiet_nan();
    }
    if (ua.is_inf() && ub.is_zero()) || (ua.is_zero() && ub.is_inf()) {
        return dst.quiet_nan();
    }
    let sign = ua.sign ^ ub.sign;
    if ua.is_inf() || ub.is_inf() {
        return dst.infinity(sign);
    }
    if ua.is_zero() || ub.is_zero() {
        return dst.zero(sign);
    }
    round_pack(sign, ua.exp + ub.exp, ua.mant * ub.mant, false, dst, rm)
}

/// Fused multiply-add `a*b + c`, everything in `fmt`, single rounding.
#[inline]
pub fn fma(fmt: FpFormat, a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
    ex_fma(fmt, fmt, a, b, c, rm)
}

/// Expanding fused multiply-add: `a, b` in `src`; `c` and the result in
/// `dst`; single rounding. This models one ExFMA unit (§II-B) — the
/// paper's baseline building block whose cascade the ExSdotp replaces.
#[inline]
pub fn ex_fma(src: FpFormat, dst: FpFormat, a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
    let ua = unpack(src, a);
    let ub = unpack(src, b);
    let uc = unpack(dst, c);
    if ua.is_nan() || ub.is_nan() || uc.is_nan() {
        return dst.quiet_nan();
    }
    if (ua.is_inf() && ub.is_zero()) || (ua.is_zero() && ub.is_inf()) {
        return dst.quiet_nan();
    }
    let psign = ua.sign ^ ub.sign;
    if ua.is_inf() || ub.is_inf() {
        // Product is ±∞.
        if uc.is_inf() && uc.sign != psign {
            return dst.quiet_nan();
        }
        return dst.infinity(psign);
    }
    if uc.is_inf() {
        return dst.infinity(uc.sign);
    }
    if ua.is_zero() || ub.is_zero() {
        // Exact-zero product: result is c (with the 0+0 sign rule).
        if uc.is_zero() {
            let sign = if psign == uc.sign { psign } else { rm == RoundingMode::Rdn };
            return dst.zero(sign);
        }
        return c & dst.width_mask();
    }
    let prod = normalize(psign, ua.exp + ub.exp, ua.mant * ub.mant);
    if uc.is_zero() {
        return round_pack(prod.sign, prod.e_msb - NORM_BIT as i32, prod.mant, false, dst, rm);
    }
    let nc = normalize(uc.sign, uc.exp, uc.mant);
    let (sign, exp, mant, sticky) = add_norm(prod, nc);
    if mant == 0 && !sticky {
        return dst.zero(rm == RoundingMode::Rdn);
    }
    round_pack(sign, exp, mant, sticky, dst, rm)
}

/// Format conversion (RISC-V `fcvt` between FP formats), correctly
/// rounded. Widening conversions are always exact.
#[inline]
pub fn cast(from: FpFormat, to: FpFormat, bits: u64, rm: RoundingMode) -> u64 {
    let u = unpack(from, bits);
    match u.class {
        Class::NaN => to.quiet_nan(),
        Class::Inf => to.infinity(u.sign),
        Class::Zero => to.zero(u.sign),
        _ => round_pack(u.sign, u.exp, u.mant, false, to, rm),
    }
}

/// IEEE comparison. `None` if unordered (either operand NaN).
pub fn cmp(fmt: FpFormat, a: u64, b: u64) -> Option<Ordering> {
    let ua = unpack(fmt, a);
    let ub = unpack(fmt, b);
    if ua.is_nan() || ub.is_nan() {
        return None;
    }
    if ua.is_zero() && ub.is_zero() {
        return Some(Ordering::Equal); // −0 == +0
    }
    Some(cmp_value(&ua, &ub))
}

fn cmp_value(ua: &Unpacked, ub: &Unpacked) -> Ordering {
    match (ua.sign, ub.sign) {
        (false, true) => return Ordering::Greater,
        (true, false) => return Ordering::Less,
        _ => {}
    }
    let mag = cmp_mag(ua, ub);
    if ua.sign {
        mag.reverse()
    } else {
        mag
    }
}

fn cmp_mag(ua: &Unpacked, ub: &Unpacked) -> Ordering {
    // Compare |a| vs |b| for finite (possibly zero) values.
    if ua.is_zero() || ub.is_zero() {
        return (!ua.is_zero() as u8).cmp(&(!ub.is_zero() as u8));
    }
    if ua.is_inf() || ub.is_inf() {
        return (ua.is_inf() as u8).cmp(&(ub.is_inf() as u8));
    }
    let ea = ua.exp + 127 - ua.mant.leading_zeros() as i32;
    let eb = ub.exp + 127 - ub.mant.leading_zeros() as i32;
    ea.cmp(&eb).then_with(|| {
        // Same MSB weight: align and compare significands.
        let la = ua.mant.leading_zeros();
        let lb = ub.mant.leading_zeros();
        (ua.mant << la).cmp(&(ub.mant << lb))
    })
}

/// RISC-V `fmin`: NaN-suppressing minimum with −0 < +0.
pub fn min(fmt: FpFormat, a: u64, b: u64) -> u64 {
    minmax(fmt, a, b, true)
}

/// RISC-V `fmax`: NaN-suppressing maximum with −0 < +0.
pub fn max(fmt: FpFormat, a: u64, b: u64) -> u64 {
    minmax(fmt, a, b, false)
}

fn minmax(fmt: FpFormat, a: u64, b: u64, want_min: bool) -> u64 {
    let a = a & fmt.width_mask();
    let b = b & fmt.width_mask();
    match (fmt.is_nan(a), fmt.is_nan(b)) {
        (true, true) => return fmt.quiet_nan(),
        (true, false) => return b,
        (false, true) => return a,
        _ => {}
    }
    // −0/+0 ordering: treat sign-distinct zeros as ordered.
    if fmt.is_zero(a) && fmt.is_zero(b) && fmt.sign(a) != fmt.sign(b) {
        let neg = if fmt.sign(a) { a } else { b };
        let pos = if fmt.sign(a) { b } else { a };
        return if want_min { neg } else { pos };
    }
    let ord = cmp(fmt, a, b).expect("NaNs handled above");
    let a_is_it = if want_min { ord != Ordering::Greater } else { ord != Ordering::Less };
    if a_is_it {
        a
    } else {
        b
    }
}

/// Sign-injection ops (RISC-V `fsgnj`, `fsgnjn`, `fsgnjx`).
pub fn sgnj(fmt: FpFormat, a: u64, b: u64) -> u64 {
    (a & !fmt.sign_mask() & fmt.width_mask()) | (b & fmt.sign_mask())
}

/// `fsgnjn`: a with negated sign of b.
pub fn sgnjn(fmt: FpFormat, a: u64, b: u64) -> u64 {
    (a & !fmt.sign_mask() & fmt.width_mask()) | ((b ^ fmt.sign_mask()) & fmt.sign_mask())
}

/// `fsgnjx`: a with sign(a) xor sign(b).
pub fn sgnjx(fmt: FpFormat, a: u64, b: u64) -> u64 {
    (a & fmt.width_mask()) ^ (b & fmt.sign_mask())
}
