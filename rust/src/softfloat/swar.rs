//! SWAR (SIMD-within-a-register) field planes and lane classification.
//!
//! The scalar fast tier ([`super::fast`]) still touches a packed
//! register one lane at a time: every lane round-trips through
//! [`super::unpack::unpack`], which re-derives its class with per-lane
//! branches. This module is the register-level alternative: treating
//! the 64-bit word as [`FormatSpec::LANES`] parallel bit fields, it
//! extracts the sign/exponent/mantissa **planes** of all lanes with a
//! handful of shared shift/mask operations, and classifies special
//! lanes (NaN/∞) for the whole register with one branch-free AND-fold.
//!
//! The planes feed the SWAR ExSdotp kernels in [`crate::exsdotp::swar`];
//! the classification is the screen those kernels use to route rare
//! special-valued registers to the scalar tier (keeping bit-identity
//! trivially) while the all-finite common case runs the lane-parallel
//! fixed-point path.
//!
//! With the `simd-nightly` cargo feature, the slice-level screen
//! ([`slice_all_finite`]) additionally processes eight packed words per
//! step through `std::simd`; the stable default is the scalar-word loop.
//! Both compute the identical predicate.

use crate::formats::spec::FormatSpec;

/// Bit `i·WIDTH` set iff lane `i` of `reg` has an all-ones exponent
/// field (NaN or ±∞). Branch-free: AND-folds every lane's exponent bits
/// down to the lane's bit 0 in `EXP_BITS − 1` shared shift/AND steps
/// (a compile-time trip count after monomorphization).
#[inline]
pub fn special_lanes<F: FormatSpec>(reg: u64) -> u64 {
    let mut acc = reg >> F::MAN_BITS;
    let mut j = 1;
    while j < F::EXP_BITS {
        acc &= reg >> (F::MAN_BITS + j);
        j += 1;
    }
    acc & F::LANE_LSB_PLANE
}

/// True when no lane of `reg` is NaN or ±∞.
#[inline]
pub fn all_finite<F: FormatSpec>(reg: u64) -> bool {
    special_lanes::<F>(reg) == 0
}

/// The sign bit of every lane, moved to the lane base (0 or 1 per lane).
#[inline]
pub fn sign_plane<F: FormatSpec>(reg: u64) -> u64 {
    (reg >> (F::WIDTH - 1)) & F::LANE_LSB_PLANE
}

/// The exponent field of every lane, moved to the lane base.
#[inline]
pub fn exp_plane<F: FormatSpec>(reg: u64) -> u64 {
    (reg >> F::MAN_BITS) & F::EXP_FIELD_PLANE
}

/// The mantissa field of every lane (already at the lane base).
#[inline]
pub fn man_plane<F: FormatSpec>(reg: u64) -> u64 {
    reg & F::MAN_FIELD_PLANE
}

/// True when no lane of any word in `words` is NaN or ±∞ — the
/// pack-once panel screen: a GEMM checks its packed operands a single
/// time, then streams them through the accumulator-screen-only SWAR
/// kernel.
#[inline]
pub fn slice_all_finite<F: FormatSpec>(words: &[u64]) -> bool {
    #[cfg(feature = "simd-nightly")]
    {
        wide::slice_all_finite_wide::<F>(words)
    }
    #[cfg(not(feature = "simd-nightly"))]
    {
        slice_all_finite_scalar::<F>(words)
    }
}

/// Stable scalar-word screen (also the differential reference for the
/// `simd-nightly` path).
#[inline]
pub fn slice_all_finite_scalar<F: FormatSpec>(words: &[u64]) -> bool {
    // OR-fold specials over short runs so the hot loop stays branch-free
    // but a special still exits early at slice scale.
    for run in words.chunks(64) {
        let mut any = 0u64;
        for &w in run {
            any |= special_lanes::<F>(w);
        }
        if any != 0 {
            return false;
        }
    }
    true
}

/// `std::simd`-accelerated slice screen: eight packed words per step.
#[cfg(feature = "simd-nightly")]
mod wide {
    use super::FormatSpec;
    use std::simd::u64x8;

    pub fn slice_all_finite_wide<F: FormatSpec>(words: &[u64]) -> bool {
        let (head, tail) = words.split_at(words.len() - words.len() % 8);
        for run in head.chunks(8 * 8) {
            let mut any = u64x8::splat(0);
            for blk in run.chunks_exact(8) {
                let v = u64x8::from_slice(blk);
                // Same AND-fold as `special_lanes`, eight words wide.
                let mut acc = v >> u64x8::splat(F::MAN_BITS as u64);
                let mut j = 1;
                while j < F::EXP_BITS {
                    acc &= v >> u64x8::splat((F::MAN_BITS + j) as u64);
                    j += 1;
                }
                any |= acc & u64x8::splat(F::LANE_LSB_PLANE);
            }
            if any.reduce_or() != 0 {
                return false;
            }
        }
        super::slice_all_finite_scalar::<F>(tail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::spec::{Fp16, Fp16alt, Fp32, Fp64, Fp8, Fp8alt};
    use crate::util::prop::for_all;

    /// Reference classification through the descriptor unpack path.
    fn special_lanes_ref<F: FormatSpec>(reg: u64) -> u64 {
        let mut out = 0u64;
        for i in 0..F::LANES {
            let lane = (reg >> (i * F::WIDTH)) & F::LANE_MASK;
            let u = crate::softfloat::unpack(F::FMT, lane);
            if u.is_nan() || u.is_inf() {
                out |= 1u64 << (i * F::WIDTH);
            }
        }
        out
    }

    fn sweep<F: FormatSpec>() {
        for_all("swar special_lanes vs unpack", 4_000, |rng| {
            let reg = rng.next_u64();
            assert_eq!(special_lanes::<F>(reg), special_lanes_ref::<F>(reg));
            // Planes agree with per-lane field extraction.
            for i in 0..F::LANES {
                let sh = i * F::WIDTH;
                let lane = (reg >> sh) & F::LANE_MASK;
                assert_eq!((sign_plane::<F>(reg) >> sh) & 1, lane >> (F::WIDTH - 1));
                assert_eq!((exp_plane::<F>(reg) >> sh) & F::EXP_FIELD_MASK, (lane >> F::MAN_BITS) & F::EXP_FIELD_MASK);
                assert_eq!((man_plane::<F>(reg) >> sh) & F::MAN_FIELD_MASK, lane & F::MAN_FIELD_MASK);
            }
        });
    }

    #[test]
    fn classification_matches_unpack_all_formats() {
        sweep::<Fp8>();
        sweep::<Fp8alt>();
        sweep::<Fp16>();
        sweep::<Fp16alt>();
        sweep::<Fp32>();
        sweep::<Fp64>();
    }

    #[test]
    fn targeted_special_patterns() {
        // FP8 e5m2: exp=11111 ⇒ 0x7c..=0x7f are Inf/NaN; 0x7b is max finite.
        assert_eq!(special_lanes::<Fp8>(0x7c), 1);
        assert_eq!(special_lanes::<Fp8>(0x7f), 1);
        assert_eq!(special_lanes::<Fp8>(0xfc), 1); // -Inf
        assert_eq!(special_lanes::<Fp8>(0x7b), 0);
        // Lane 3 of eight.
        assert_eq!(special_lanes::<Fp8>(0x7c << 24), 1 << 24);
        // FP16 +Inf in lane 2, NaN in lane 0.
        let reg = (0x7c00u64 << 32) | 0x7e00;
        assert_eq!(special_lanes::<Fp16>(reg), (1 << 32) | 1);
        assert!(!all_finite::<Fp16>(reg));
        // Subnormals, zeros and max-finite lanes are all finite.
        assert!(all_finite::<Fp16>(0x0001_8000_03ff_7bff));
    }

    #[test]
    fn slice_screen_matches_wordwise() {
        for_all("slice_all_finite vs per-word", 300, |rng| {
            let n = (rng.below(200) + 1) as usize;
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() & 0x7b7b_7b7b_7b7b_7b7b).collect();
            assert!(slice_all_finite::<Fp8>(&v), "masked words have no special exp fields");
            // Inject one special lane at a random word.
            let at = rng.below(n as u64) as usize;
            v[at] |= 0x7cu64 << (8 * rng.below(8));
            assert!(!slice_all_finite::<Fp8>(&v));
            assert_eq!(slice_all_finite_scalar::<Fp8>(&v), slice_all_finite::<Fp8>(&v));
        });
    }
}
