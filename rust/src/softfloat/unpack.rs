//! Decoding format encodings into exact (sign, exponent, significand)
//! triples.

use crate::formats::FpFormat;

/// IEEE value class.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Class {
    /// ±0.
    Zero,
    /// Subnormal (nonzero, zero exponent field).
    Subnormal,
    /// Normal finite.
    Normal,
    /// ±∞.
    Inf,
    /// Any NaN (we do not distinguish signaling: FPnew quietens all).
    NaN,
}

/// An exactly decoded value: for finite nonzero, `value = (-1)^sign *
/// mant * 2^exp` with `mant` the integer significand (hidden bit
/// included for normals).
#[derive(Clone, Copy, Debug)]
pub struct Unpacked {
    /// Sign bit.
    pub sign: bool,
    /// Power-of-two weight of `mant`'s LSB.
    pub exp: i32,
    /// Integer significand (0 for zero/inf/nan).
    pub mant: u128,
    /// Value class.
    pub class: Class,
}

impl Unpacked {
    /// True for Zero/Subnormal/Normal.
    pub fn is_finite(&self) -> bool {
        matches!(self.class, Class::Zero | Class::Subnormal | Class::Normal)
    }

    /// True for NaN.
    pub fn is_nan(&self) -> bool {
        matches!(self.class, Class::NaN)
    }

    /// True for ±∞.
    pub fn is_inf(&self) -> bool {
        matches!(self.class, Class::Inf)
    }

    /// True for ±0.
    pub fn is_zero(&self) -> bool {
        matches!(self.class, Class::Zero)
    }
}

/// Decode `bits` (an encoding in `fmt`, low `fmt.width()` bits) exactly.
///
/// `#[inline]`: when called with a constant format (the monomorphized
/// [`crate::softfloat::fast`] tier) the field extraction folds to fixed
/// shifts/masks.
#[inline]
pub fn unpack(fmt: FpFormat, bits: u64) -> Unpacked {
    let bits = bits & fmt.width_mask();
    let (sign, exp_field, man_field) = fmt.split(bits);
    if exp_field == fmt.exp_special() {
        return Unpacked {
            sign,
            exp: 0,
            mant: 0,
            class: if man_field == 0 { Class::Inf } else { Class::NaN },
        };
    }
    if exp_field == 0 {
        if man_field == 0 {
            return Unpacked { sign, exp: 0, mant: 0, class: Class::Zero };
        }
        // Subnormal: value = man_field * 2^(emin - man_bits).
        return Unpacked {
            sign,
            exp: fmt.emin() - fmt.man_bits as i32,
            mant: man_field as u128,
            class: Class::Subnormal,
        };
    }
    // Normal: value = (1.man) * 2^(exp_field - bias)
    //               = (man_field | hidden) * 2^(exp_field - bias - man_bits).
    Unpacked {
        sign,
        exp: exp_field as i32 - fmt.bias() - fmt.man_bits as i32,
        mant: (man_field | (1 << fmt.man_bits)) as u128,
        class: Class::Normal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8ALT, PAPER_FORMATS};
    use crate::softfloat::round::{round_pack, RoundingMode};

    #[test]
    fn unpack_classes() {
        assert!(matches!(unpack(FP16, 0x0000).class, Class::Zero));
        assert!(matches!(unpack(FP16, 0x8000).class, Class::Zero));
        assert!(matches!(unpack(FP16, 0x0001).class, Class::Subnormal));
        assert!(matches!(unpack(FP16, 0x3c00).class, Class::Normal));
        assert!(matches!(unpack(FP16, 0x7c00).class, Class::Inf));
        assert!(matches!(unpack(FP16, 0x7e00).class, Class::NaN));
    }

    #[test]
    fn unpack_values() {
        // FP32 1.0
        let u = unpack(FP32, 0x3f80_0000);
        assert_eq!((u.mant as i64).checked_shl(0).unwrap(), 1 << 23);
        assert_eq!(u.exp, -23);
        // FP8alt 1.5 = 0 0111 100
        let u = unpack(FP8ALT, 0b0_0111_100);
        assert_eq!(u.mant, 0b1100);
        assert_eq!(u.exp, -3);
        assert!(!u.sign);
    }

    #[test]
    fn unpack_roundpack_roundtrip_all_finite() {
        // Every finite encoding must survive unpack → round_pack exactly,
        // in every rounding mode (it is already on the grid).
        for fmt in PAPER_FORMATS {
            if fmt.width() > 16 {
                continue; // exhaustive only for narrow formats
            }
            for bits in 0..(1u64 << fmt.width()) {
                if fmt.is_nan(bits) || fmt.is_inf(bits) {
                    continue;
                }
                let u = unpack(fmt, bits);
                for rm in [RoundingMode::Rne, RoundingMode::Rtz, RoundingMode::Rup, RoundingMode::Rdn, RoundingMode::Rmm] {
                    let re = round_pack(u.sign, u.exp, u.mant, false, fmt, rm);
                    assert_eq!(re, bits, "fmt={} bits={bits:#x} rm={rm:?}", fmt.name());
                }
            }
        }
    }
}
