//! Monomorphized softfloat kernels — Tier A of the batch numerics
//! engine.
//!
//! Every function here is the compile-time-dispatched twin of a
//! [`crate::softfloat`] routine: generic over [`FormatSpec`] (and, for
//! expanding ops, a `(src, dst)` pair), calling the **same**
//! implementation with the constant [`FormatSpec::FMT`]. Because the
//! shared implementations are `#[inline]`, each instantiation
//! constant-folds the format parameters into fixed shifts, masks and
//! grid positions — the software analogue of elaborating one hardware
//! instance per format, and the reason the batch engine
//! ([`crate::batch`]) runs circles around the descriptor-dispatched
//! path without being able to diverge from it numerically.
//!
//! Naming: `*_m` = monomorphized. `add_m::<Fp16>` is `add(FP16, ..)`,
//! `ex_fma_m::<Fp8, Fp16>` is `ex_fma(FP8, FP16, ..)`, and so on.

use super::convert;
use super::ops;
use super::round::{round_pack, RoundingMode};
use super::unpack::{unpack, Unpacked};
use crate::formats::spec::FormatSpec;

/// Monomorphized [`unpack`].
#[inline]
pub fn unpack_m<F: FormatSpec>(bits: u64) -> Unpacked {
    unpack(F::FMT, bits)
}

/// Monomorphized [`round_pack`].
#[inline]
pub fn round_pack_m<F: FormatSpec>(sign: bool, exp: i32, mant: u128, sticky: bool, rm: RoundingMode) -> u64 {
    round_pack(sign, exp, mant, sticky, F::FMT, rm)
}

/// Monomorphized IEEE addition.
#[inline]
pub fn add_m<F: FormatSpec>(a: u64, b: u64, rm: RoundingMode) -> u64 {
    ops::add(F::FMT, a, b, rm)
}

/// Monomorphized IEEE multiplication.
#[inline]
pub fn mul_m<F: FormatSpec>(a: u64, b: u64, rm: RoundingMode) -> u64 {
    ops::mul(F::FMT, a, b, rm)
}

/// Monomorphized fused multiply-add.
#[inline]
pub fn fma_m<F: FormatSpec>(a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
    ops::fma(F::FMT, a, b, c, rm)
}

/// Monomorphized expanding FMA: `a, b` in `S`; `c`, result in `D`.
#[inline]
pub fn ex_fma_m<S: FormatSpec, D: FormatSpec>(a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
    ops::ex_fma(S::FMT, D::FMT, a, b, c, rm)
}

/// Monomorphized format conversion `S → D`.
#[inline]
pub fn cast_m<S: FormatSpec, D: FormatSpec>(bits: u64, rm: RoundingMode) -> u64 {
    ops::cast(S::FMT, D::FMT, bits, rm)
}

/// Monomorphized `f64 → F` encoding.
#[inline]
pub fn from_f64_m<F: FormatSpec>(x: f64, rm: RoundingMode) -> u64 {
    convert::from_f64(x, F::FMT, rm)
}

/// Monomorphized `F → f64` decoding (exact).
#[inline]
pub fn to_f64_m<F: FormatSpec>(bits: u64) -> f64 {
    convert::to_f64(bits, F::FMT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::spec::{Fp16, Fp32, Fp8, Fp8alt};
    use crate::formats::{FP16, FP32, FP8, FP8ALT};
    use crate::softfloat::{add, cast, ex_fma, fma, from_f64, mul, to_f64};
    use crate::util::prop::{for_all, FpGen};

    const RMS: [RoundingMode; 5] = [
        RoundingMode::Rne,
        RoundingMode::Rtz,
        RoundingMode::Rdn,
        RoundingMode::Rup,
        RoundingMode::Rmm,
    ];

    #[test]
    fn monomorphized_ops_bit_identical_to_descriptor_path() {
        // Exhaustive over FP8 encodings (incl. NaN/Inf/subnormal/±0),
        // every rounding mode.
        for a in 0..256u64 {
            for b in 0..256u64 {
                for rm in RMS {
                    assert_eq!(add_m::<Fp8>(a, b, rm), add(FP8, a, b, rm));
                    assert_eq!(mul_m::<Fp8>(a, b, rm), mul(FP8, a, b, rm));
                    assert_eq!(cast_m::<Fp8, Fp16>(a, rm), cast(FP8, FP16, a, rm));
                    assert_eq!(cast_m::<Fp8alt, Fp16>(a, rm), cast(FP8ALT, FP16, a, rm));
                }
            }
        }
    }

    #[test]
    fn monomorphized_fma_and_exfma_match_randomized() {
        let g16 = FpGen::new(FP16);
        let g32 = FpGen::new(FP32);
        for_all("fast fma/ex_fma vs descriptor", 20_000, |rng| {
            let (a, b) = (g16.any(rng), g16.any(rng));
            let c16 = g16.any(rng);
            let c32 = g32.any(rng);
            for rm in RMS {
                assert_eq!(fma_m::<Fp16>(a, b, c16, rm), fma(FP16, a, b, c16, rm));
                assert_eq!(ex_fma_m::<Fp16, Fp32>(a, b, c32, rm), ex_fma(FP16, FP32, a, b, c32, rm));
            }
        });
    }

    #[test]
    fn monomorphized_conversions_match() {
        let mut rng = crate::util::rng::Rng::new(31);
        for _ in 0..10_000 {
            let x = rng.gaussian() * 2f64.powi((rng.below(41) as i32) - 20);
            for rm in RMS {
                assert_eq!(from_f64_m::<Fp8>(x, rm), from_f64(x, FP8, rm));
                assert_eq!(from_f64_m::<Fp16>(x, rm), from_f64(x, FP16, rm));
            }
            let b16 = rng.next_u64() & 0xffff;
            assert_eq!(to_f64_m::<Fp16>(b16).to_bits(), to_f64(b16, FP16).to_bits());
        }
    }
}
