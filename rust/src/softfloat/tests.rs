//! Cross-validation of the softfloat core.
//!
//! Three independent oracles:
//! 1. **Native f32/f64 hardware** — FP32/FP64 add/mul/FMA must agree
//!    bit-for-bit with the host FPU (IEEE RNE), including NaN → our
//!    canonical qNaN policy.
//! 2. **Exact f64 arithmetic for narrow formats** — FP8/FP8alt
//!    operations are exact in f64 (≤4-bit significands, tiny exponent
//!    range), so `round(f64-exact)` is a correct single-rounding oracle;
//!    we test *exhaustively* over all 256×256 operand pairs.
//! 3. **Algebraic properties** — commutativity, sign symmetry,
//!    monotonicity, cast roundtrips — via the in-crate property driver.

use super::ops::*;
use super::round::RoundingMode;
use crate::formats::*;
use crate::softfloat::{from_f64, to_f64};
use crate::util::prop::{for_all, FpGen};

const RMS: [RoundingMode; 5] = [
    RoundingMode::Rne,
    RoundingMode::Rtz,
    RoundingMode::Rdn,
    RoundingMode::Rup,
    RoundingMode::Rmm,
];

/// Compare results treating every NaN as equivalent (we always produce
/// the canonical quiet NaN; hardware may produce payloads).
fn same(fmt: FpFormat, ours: u64, reference: u64) -> bool {
    if fmt.is_nan(ours) && fmt.is_nan(reference) {
        return true;
    }
    ours == reference
}

// ---------------------------------------------------------------- FP32 vs native

#[test]
fn fp32_add_matches_hardware() {
    for_all("fp32 add vs f32", 20_000, |rng| {
        let g = FpGen::new(FP32);
        let (a, b) = (g.any(rng), g.any(rng));
        let ours = add(FP32, a, b, RoundingMode::Rne);
        let hw = (f32::from_bits(a as u32) + f32::from_bits(b as u32)).to_bits() as u64;
        assert!(same(FP32, ours, hw), "a={a:#010x} b={b:#010x} ours={ours:#010x} hw={hw:#010x}");
    });
}

#[test]
fn fp32_mul_matches_hardware() {
    for_all("fp32 mul vs f32", 20_000, |rng| {
        let g = FpGen::new(FP32);
        let (a, b) = (g.any(rng), g.any(rng));
        let ours = mul(FP32, a, b, RoundingMode::Rne);
        let hw = (f32::from_bits(a as u32) * f32::from_bits(b as u32)).to_bits() as u64;
        assert!(same(FP32, ours, hw), "a={a:#010x} b={b:#010x} ours={ours:#010x} hw={hw:#010x}");
    });
}

#[test]
fn fp32_fma_matches_hardware() {
    for_all("fp32 fma vs f32::mul_add", 20_000, |rng| {
        let g = FpGen::new(FP32);
        let (a, b, c) = (g.any(rng), g.any(rng), g.any(rng));
        let ours = fma(FP32, a, b, c, RoundingMode::Rne);
        let hw = f32::from_bits(a as u32)
            .mul_add(f32::from_bits(b as u32), f32::from_bits(c as u32))
            .to_bits() as u64;
        assert!(
            same(FP32, ours, hw),
            "a={a:#010x} b={b:#010x} c={c:#010x} ours={ours:#010x} hw={hw:#010x}"
        );
    });
}

#[test]
fn fp64_add_mul_match_hardware() {
    for_all("fp64 ops vs f64", 20_000, |rng| {
        let g = FpGen::new(FP64);
        let (a, b) = (g.any(rng), g.any(rng));
        let s = add(FP64, a, b, RoundingMode::Rne);
        let hs = (f64::from_bits(a) + f64::from_bits(b)).to_bits();
        assert!(same(FP64, s, hs), "add a={a:#x} b={b:#x}");
        let p = mul(FP64, a, b, RoundingMode::Rne);
        let hp = (f64::from_bits(a) * f64::from_bits(b)).to_bits();
        assert!(same(FP64, p, hp), "mul a={a:#x} b={b:#x}");
    });
}

#[test]
fn fp64_fma_matches_hardware() {
    for_all("fp64 fma vs f64::mul_add", 10_000, |rng| {
        let g = FpGen::new(FP64);
        let (a, b, c) = (g.any(rng), g.any(rng), g.any(rng));
        let ours = fma(FP64, a, b, c, RoundingMode::Rne);
        let hw = f64::from_bits(a).mul_add(f64::from_bits(b), f64::from_bits(c)).to_bits();
        assert!(same(FP64, ours, hw), "a={a:#x} b={b:#x} c={c:#x}");
    });
}

// ------------------------------------------------- FP8/FP8alt exhaustive vs f64

/// f64 computation is exact for any two FP8/FP8alt/FP16 operands under
/// +, ×; rounding that exact value into the narrow format once is the
/// IEEE-correct result.
fn check_narrow_binop(
    fmt: FpFormat,
    rm: RoundingMode,
    is_add: bool,
    op: impl Fn(f64, f64) -> f64,
    ours: impl Fn(u64, u64) -> u64,
) {
    let w = fmt.width();
    for a in 0..(1u64 << w) {
        for b in 0..(1u64 << w) {
            let got = ours(a, b);
            let fa = to_f64(a, fmt);
            let fb = to_f64(b, fmt);
            let exact = op(fa, fb);
            let mut want = from_f64(exact, fmt, rm);
            // The host FPU runs in RNE, so the sign of an exact-zero sum
            // doesn't reflect `rm`; patch it with the IEEE rule.
            if is_add && exact == 0.0 && !exact.is_nan() {
                let sign = if fa == 0.0 && fa.is_sign_negative() == fb.is_sign_negative() && fb == 0.0 {
                    fa.is_sign_negative()
                } else {
                    rm == RoundingMode::Rdn
                };
                want = fmt.zero(sign);
            }
            // `from_f64(exact)` is single-rounded because `exact` is
            // exactly representable in f64.
            assert!(
                same(fmt, got, want),
                "{} rm={rm:?} a={a:#x} b={b:#x} got={got:#x} want={want:#x}",
                fmt.name()
            );
        }
    }
}

#[test]
fn fp8_add_exhaustive_all_modes() {
    for rm in RMS {
        check_narrow_binop(FP8, rm, true, |x, y| x + y, |a, b| add(FP8, a, b, rm));
    }
}

#[test]
fn fp8alt_add_exhaustive_all_modes() {
    for rm in RMS {
        check_narrow_binop(FP8ALT, rm, true, |x, y| x + y, |a, b| add(FP8ALT, a, b, rm));
    }
}

#[test]
fn fp8_mul_exhaustive_all_modes() {
    for rm in RMS {
        check_narrow_binop(FP8, rm, false, |x, y| x * y, |a, b| mul(FP8, a, b, rm));
    }
}

#[test]
fn fp8alt_mul_exhaustive_all_modes() {
    for rm in RMS {
        check_narrow_binop(FP8ALT, rm, false, |x, y| x * y, |a, b| mul(FP8ALT, a, b, rm));
    }
}

#[test]
fn fp16_add_random_vs_exact_f64() {
    // FP16 sums are exact in f64 (≤ 50 significant bits needed).
    for_all("fp16 add vs exact", 50_000, |rng| {
        let g = FpGen::new(FP16);
        let (a, b) = (g.any(rng), g.any(rng));
        for rm in RMS {
            let got = add(FP16, a, b, rm);
            let fa = to_f64(a, FP16);
            let fb = to_f64(b, FP16);
            let exact = fa + fb;
            let mut want = from_f64(exact, FP16, rm);
            if exact == 0.0 {
                let sign = if fa == 0.0 && fb == 0.0 && fa.is_sign_negative() == fb.is_sign_negative() {
                    fa.is_sign_negative()
                } else {
                    rm == RoundingMode::Rdn
                };
                want = FP16.zero(sign);
            }
            assert!(same(FP16, got, want), "rm={rm:?} a={a:#x} b={b:#x}");
        }
    });
}

#[test]
fn fp16_mul_random_vs_exact_f64() {
    // FP16 products are exact in f64 (22 significant bits).
    for_all("fp16 mul vs exact", 50_000, |rng| {
        let g = FpGen::new(FP16);
        let (a, b) = (g.any(rng), g.any(rng));
        for rm in RMS {
            let got = mul(FP16, a, b, rm);
            let want = from_f64(to_f64(a, FP16) * to_f64(b, FP16), FP16, rm);
            assert!(same(FP16, got, want), "rm={rm:?} a={a:#x} b={b:#x}");
        }
    });
}

// ---------------------------------------------------------------- expanding FMA

#[test]
fn ex_fma_fp16_to_fp32_vs_hardware() {
    // FP16 sources are exact f32 values, and f32::mul_add rounds once —
    // exactly the ExFMA semantics for src=FP16, dst=FP32.
    for_all("exfma 16->32 vs f32 mul_add", 30_000, |rng| {
        let g = FpGen::new(FP16);
        let gd = FpGen::new(FP32);
        let (a, b, c) = (g.any(rng), g.any(rng), gd.any(rng));
        let ours = ex_fma(FP16, FP32, a, b, c, RoundingMode::Rne);
        let af = to_f64(a, FP16) as f32;
        let bf = to_f64(b, FP16) as f32;
        let hw = af.mul_add(bf, f32::from_bits(c as u32)).to_bits() as u64;
        assert!(same(FP32, ours, hw), "a={a:#x} b={b:#x} c={c:#x} ours={ours:#x} hw={hw:#x}");
    });
}

#[test]
fn ex_fma_fp8_to_fp16_vs_exact_f64() {
    // An FP8×FP8 product (≤ 6 significant bits) plus an FP16 addend is
    // exact in f64 (needs ≤ 64+11 bits? No: product exp range ±30, FP16
    // grid down to 2^-24 — max alignment ~60 bits, plus 11 mantissa bits
    // exceeds 53!). Use exhaustive small-exponent filtering instead:
    // restrict c to values whose exponent is within ±20 of the product
    // so f64 holds the sum exactly.
    let gs = FpGen::new(FP8);
    let gd = FpGen::new(FP16);
    for_all("exfma 8->16 vs exact", 50_000, |rng| {
        let (a, b, c) = (gs.any(rng), gs.any(rng), gd.any(rng));
        let pa = to_f64(a, FP8) * to_f64(b, FP8); // exact: 6 bits
        let cv = to_f64(c, FP16);
        // Skip cases where the f64 sum might be inexact (alignment > 47).
        if pa != 0.0 && cv != 0.0 && pa.is_finite() && cv.is_finite() {
            let ea = pa.abs().log2();
            let ec = cv.abs().log2();
            if (ea - ec).abs() > 40.0 {
                return;
            }
        }
        let ours = ex_fma(FP8, FP16, a, b, c, RoundingMode::Rne);
        let want = from_f64(pa + cv, FP16, RoundingMode::Rne);
        assert!(same(FP16, ours, want), "a={a:#x} b={b:#x} c={c:#x}");
    });
}

// ---------------------------------------------------------------------- casts

#[test]
fn widening_casts_are_exact_and_roundtrip() {
    let pairs = [(FP8, FP16), (FP8ALT, FP16), (FP16, FP32), (FP16ALT, FP32), (FP8, FP32), (FP32, FP64)];
    for (narrow, wide) in pairs {
        if narrow.width() > 16 {
            continue;
        }
        for bits in 0..(1u64 << narrow.width()) {
            let up = cast(narrow, wide, bits, RoundingMode::Rne);
            if narrow.is_nan(bits) {
                assert!(wide.is_nan(up));
                continue;
            }
            assert_eq!(to_f64(up, wide), to_f64(bits, narrow), "{}→{} bits={bits:#x}", narrow.name(), wide.name());
            let down = cast(wide, narrow, up, RoundingMode::Rne);
            assert_eq!(down, bits, "{}→{}→back bits={bits:#x}", narrow.name(), wide.name());
        }
    }
}

#[test]
fn fp32_to_fp16_cast_matches_exact() {
    for_all("cast 32→16", 50_000, |rng| {
        let g = FpGen::new(FP32);
        let a = g.any(rng);
        for rm in RMS {
            let got = cast(FP32, FP16, a, rm);
            let want = from_f64(f32::from_bits(a as u32) as f64, FP16, rm);
            assert!(same(FP16, got, want), "a={a:#x} rm={rm:?}");
        }
    });
}

#[test]
fn cast_fp16_fp16alt_loses_precision_predictably() {
    // 1 + 2^-10 is representable in FP16 (10 mantissa bits) but not in
    // FP16alt (7 bits) — RNE snaps to 1.0.
    let x = from_f64(1.0 + 2f64.powi(-10), FP16, RoundingMode::Rne);
    assert_eq!(to_f64(x, FP16), 1.0 + 2f64.powi(-10));
    let y = cast(FP16, FP16ALT, x, RoundingMode::Rne);
    assert_eq!(to_f64(y, FP16ALT), 1.0);
    // And FP16alt's range exceeds FP16's: 2^100 survives 16alt→32 but
    // overflows 16.
    let big = from_f64(2f64.powi(100), FP16ALT, RoundingMode::Rne);
    assert_eq!(to_f64(big, FP16ALT), 2f64.powi(100));
    assert!(FP16.is_inf(cast(FP16ALT, FP16, big, RoundingMode::Rne)));
}

// ------------------------------------------------------------------ properties

#[test]
fn add_mul_commute() {
    for fmt in PAPER_FORMATS {
        let g = FpGen::new(fmt);
        for_all("commutativity", 5_000, |rng| {
            let (a, b) = (g.any(rng), g.any(rng));
            for rm in RMS {
                assert!(same(fmt, add(fmt, a, b, rm), add(fmt, b, a, rm)));
                assert!(same(fmt, mul(fmt, a, b, rm), mul(fmt, b, a, rm)));
            }
        });
    }
}

#[test]
fn mul_sign_symmetry() {
    for fmt in [FP16, FP8, FP8ALT] {
        let g = FpGen::new(fmt);
        for_all("sign symmetry", 5_000, |rng| {
            let (a, b) = (g.finite(rng), g.finite(rng));
            let p = mul(fmt, a, b, RoundingMode::Rne);
            let pn = mul(fmt, a ^ fmt.sign_mask(), b, RoundingMode::Rne);
            if !fmt.is_nan(p) {
                assert_eq!(p ^ fmt.sign_mask(), pn);
            }
        });
    }
}

#[test]
fn rounding_mode_bracketing() {
    // RDN ≤ RNE ≤ RUP as real values, for finite results.
    for fmt in [FP16, FP8, FP8ALT, FP16ALT] {
        let g = FpGen::new(fmt);
        for_all("bracketing", 5_000, |rng| {
            let (a, b) = (g.finite(rng), g.finite(rng));
            let dn = to_f64(add(fmt, a, b, RoundingMode::Rdn), fmt);
            let ne = to_f64(add(fmt, a, b, RoundingMode::Rne), fmt);
            let up = to_f64(add(fmt, a, b, RoundingMode::Rup), fmt);
            if dn.is_finite() && up.is_finite() {
                assert!(dn <= ne && ne <= up, "a={a:#x} b={b:#x} dn={dn} ne={ne} up={up}");
            }
        });
    }
}

#[test]
fn fma_reduces_to_mul_when_c_zero_and_to_add_when_b_one() {
    for fmt in [FP16, FP8ALT] {
        let g = FpGen::new(fmt);
        let one = from_f64(1.0, fmt, RoundingMode::Rne);
        for_all("fma degenerate", 5_000, |rng| {
            let (a, c) = (g.finite(rng), g.finite(rng));
            // a*1 + c == a + c
            assert!(same(
                fmt,
                fma(fmt, a, one, c, RoundingMode::Rne),
                add(fmt, a, c, RoundingMode::Rne)
            ));
        });
    }
}

#[test]
fn nan_propagation_everywhere() {
    for fmt in PAPER_FORMATS {
        let nan = fmt.quiet_nan();
        let one = from_f64(1.0, fmt, RoundingMode::Rne);
        assert!(fmt.is_nan(add(fmt, nan, one, RoundingMode::Rne)));
        assert!(fmt.is_nan(mul(fmt, nan, one, RoundingMode::Rne)));
        assert!(fmt.is_nan(fma(fmt, nan, one, one, RoundingMode::Rne)));
        assert!(fmt.is_nan(fma(fmt, one, one, nan, RoundingMode::Rne)));
        assert!(FP32.is_nan(cast(fmt, FP32, nan, RoundingMode::Rne)));
    }
}

#[test]
fn inf_arithmetic() {
    for fmt in PAPER_FORMATS {
        let inf = fmt.infinity(false);
        let ninf = fmt.infinity(true);
        let one = from_f64(1.0, fmt, RoundingMode::Rne);
        let zero = fmt.zero(false);
        assert_eq!(add(fmt, inf, one, RoundingMode::Rne), inf);
        assert!(fmt.is_nan(add(fmt, inf, ninf, RoundingMode::Rne)));
        assert!(fmt.is_nan(mul(fmt, inf, zero, RoundingMode::Rne)));
        assert_eq!(mul(fmt, inf, ninf, RoundingMode::Rne), ninf);
        assert!(fmt.is_nan(fma(fmt, zero, inf, one, RoundingMode::Rne)));
    }
}

#[test]
fn signed_zero_rules() {
    for fmt in [FP16, FP8, FP32] {
        let pz = fmt.zero(false);
        let nz = fmt.zero(true);
        assert_eq!(add(fmt, pz, nz, RoundingMode::Rne), pz);
        assert_eq!(add(fmt, pz, nz, RoundingMode::Rdn), nz);
        assert_eq!(add(fmt, nz, nz, RoundingMode::Rne), nz);
        // x + (−x) = +0 (RNE), −0 (RDN).
        let x = from_f64(1.5, fmt, RoundingMode::Rne);
        let mx = x | fmt.sign_mask();
        assert_eq!(add(fmt, x, mx, RoundingMode::Rne), pz);
        assert_eq!(add(fmt, x, mx, RoundingMode::Rdn), nz);
    }
}

// ---------------------------------------------------------------- compare / minmax

#[test]
fn compare_and_minmax() {
    use std::cmp::Ordering;
    let one = from_f64(1.0, FP16, RoundingMode::Rne);
    let two = from_f64(2.0, FP16, RoundingMode::Rne);
    let m1 = one | FP16.sign_mask();
    assert_eq!(cmp(FP16, one, two), Some(Ordering::Less));
    assert_eq!(cmp(FP16, two, one), Some(Ordering::Greater));
    assert_eq!(cmp(FP16, m1, one), Some(Ordering::Less));
    assert_eq!(cmp(FP16, FP16.zero(true), FP16.zero(false)), Some(Ordering::Equal));
    assert_eq!(cmp(FP16, FP16.quiet_nan(), one), None);

    assert_eq!(min(FP16, one, two), one);
    assert_eq!(max(FP16, m1, one), one);
    // NaN-suppressing.
    assert_eq!(min(FP16, FP16.quiet_nan(), two), two);
    assert_eq!(max(FP16, two, FP16.quiet_nan()), two);
    assert_eq!(min(FP16, FP16.quiet_nan(), FP16.quiet_nan()), FP16.quiet_nan());
    // −0 < +0 for min/max.
    assert_eq!(min(FP16, FP16.zero(false), FP16.zero(true)), FP16.zero(true));
    assert_eq!(max(FP16, FP16.zero(false), FP16.zero(true)), FP16.zero(false));
}

#[test]
fn compare_agrees_with_f64_ordering() {
    for fmt in [FP16, FP8, FP8ALT, FP16ALT] {
        let g = FpGen::new(fmt);
        for_all("cmp vs f64", 10_000, |rng| {
            let (a, b) = (g.any(rng), g.any(rng));
            let ours = cmp(fmt, a, b);
            let fa = to_f64(a, fmt);
            let fb = to_f64(b, fmt);
            let want = fa.partial_cmp(&fb);
            assert_eq!(ours, want, "{} a={a:#x} b={b:#x}", fmt.name());
        });
    }
}

#[test]
fn sign_injection() {
    let x = from_f64(1.5, FP16, RoundingMode::Rne);
    let neg = from_f64(-2.0, FP16, RoundingMode::Rne);
    assert_eq!(to_f64(sgnj(FP16, x, neg), FP16), -1.5);
    assert_eq!(to_f64(sgnjn(FP16, x, neg), FP16), 1.5);
    assert_eq!(to_f64(sgnjx(FP16, neg, neg), FP16), 2.0);
}

#[test]
fn classify_all_classes() {
    assert_eq!(classify(FP16, FP16.infinity(true)), FpClass::NegInf);
    assert_eq!(classify(FP16, from_f64(-1.0, FP16, RoundingMode::Rne)), FpClass::NegNormal);
    assert_eq!(classify(FP16, 0x8001), FpClass::NegSubnormal);
    assert_eq!(classify(FP16, 0x8000), FpClass::NegZero);
    assert_eq!(classify(FP16, 0x0000), FpClass::PosZero);
    assert_eq!(classify(FP16, 0x0001), FpClass::PosSubnormal);
    assert_eq!(classify(FP16, 0x3c00), FpClass::PosNormal);
    assert_eq!(classify(FP16, FP16.infinity(false)), FpClass::PosInf);
    assert_eq!(classify(FP16, FP16.quiet_nan()), FpClass::QuietNan);
    assert_eq!(classify(FP16, 0x7d00 & !0x0200), FpClass::SignalingNan);
}
