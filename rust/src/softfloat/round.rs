//! The single shared rounding/packing step.
//!
//! All emulated units — scalar FPU ops, the ExFMA cascade baseline, and
//! the fused ExSdotp datapath — terminate in [`round_pack`]: an exact
//! (significand, exponent, sticky) triple is rounded once into a target
//! [`FpFormat`]. Centralizing this guarantees that accuracy differences
//! measured in Table IV come from the *datapath* (one rounding vs. two),
//! not from inconsistent rounding implementations.
//!
//! # Stochastic rounding
//!
//! [`RoundingMode::StochasticRound`] carries a 64-bit key and rounds up
//! with probability equal to the dropped fraction (resolved to 32 bits),
//! the unbiased scheme Wang et al. (1812.08011) use to rescue FP8
//! training. The draw is a pure function of the key — no global RNG, no
//! state — so a rounding is deterministic wherever and whenever it
//! executes. Callers derive per-site keys from the session seed with the
//! `sr_*` helpers below ([`RoundingMode::sr_element`],
//! [`RoundingMode::sr_lane`], …), which are the **identity on every
//! non-stochastic mode**: threading them through the kernels changes
//! nothing unless a session explicitly opts into stochastic rounding.
//! The derivation discipline (who mixes which index where) is pinned in
//! DESIGN.md's "Accuracy-at-scale numerics" section; the differential
//! tests pin the consequence — SR results are bit-identical across
//! thread counts, lane tiers and executor backends.

use crate::formats::FpFormat;

/// One avalanche round of the splitmix64 finalizer over `a` mixed with
/// `b` — the key-derivation primitive behind every `sr_*` helper. Full
/// 64-bit avalanche: any differing input bit flips each output bit with
/// probability ~1/2, so derived keys are statistically independent even
/// for adjacent indices.
pub const fn sr_mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// Domain tags for the `sr_*` derivation helpers: each index space is
// salted into its own top byte so `sr_lane(3)` can never collide with
// `sr_level(3)` on the same key.
const SR_DOM_LANE: u64 = 0x01 << 56;
const SR_DOM_LEVEL: u64 = 0x02 << 56;
const SR_DOM_ELEMENT: u64 = 0x03 << 56;
const SR_DOM_STEP: u64 = 0x04 << 56;
const SR_DOM_TREE: u64 = 0x05 << 56;
const SR_DOM_FOLD: u64 = 0x06 << 56;
const SR_DOM_RUN: u64 = 0x07 << 56;
/// Domain separator between a rounding site's key and its Bernoulli
/// draw, so the draw never equals a child key derived from the same key.
const SR_DOM_DRAW: u64 = 0x0f << 56;

/// RISC-V `frm` rounding modes, plus the software-level stochastic mode.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RoundingMode {
    /// Round to nearest, ties to even (`frm=000`).
    Rne,
    /// Round towards zero (`frm=001`).
    Rtz,
    /// Round down, towards −∞ (`frm=010`).
    Rdn,
    /// Round up, towards +∞ (`frm=011`).
    Rup,
    /// Round to nearest, ties to max magnitude (`frm=100`).
    Rmm,
    /// Seeded stochastic rounding: round up with probability equal to
    /// the dropped fraction, drawn deterministically from the carried
    /// key (derived from the session seed and the rounding site — see
    /// the `sr_*` helpers). Uses the reserved `frm=101` encoding; the
    /// hardware CSR decoder does not accept it
    /// ([`RoundingMode::from_frm`] still returns `None` for `0b101`),
    /// because the cycle-accurate engine has no SR datapath — only the
    /// functional engine runs it.
    StochasticRound(u64),
}

impl RoundingMode {
    /// RISC-V `frm` encoding (stochastic rounding reports the reserved
    /// `0b101` slot; the key does not fit in a CSR and is dropped).
    pub const fn to_frm(self) -> u32 {
        match self {
            RoundingMode::Rne => 0b000,
            RoundingMode::Rtz => 0b001,
            RoundingMode::Rdn => 0b010,
            RoundingMode::Rup => 0b011,
            RoundingMode::Rmm => 0b100,
            RoundingMode::StochasticRound(_) => 0b101,
        }
    }

    /// Decode a RISC-V `frm` field. `0b101` decodes to `None`: the
    /// stochastic mode is a software construct whose key cannot round-
    /// trip through a 3-bit CSR field, so hardware-facing decoders fall
    /// back to RNE exactly as they do for any reserved encoding.
    pub const fn from_frm(frm: u32) -> Option<Self> {
        match frm {
            0b000 => Some(RoundingMode::Rne),
            0b001 => Some(RoundingMode::Rtz),
            0b010 => Some(RoundingMode::Rdn),
            0b011 => Some(RoundingMode::Rup),
            0b100 => Some(RoundingMode::Rmm),
            _ => None,
        }
    }

    /// Is this the stochastic mode (any key)?
    pub const fn is_stochastic(self) -> bool {
        matches!(self, RoundingMode::StochasticRound(_))
    }

    /// Core key derivation: mix `salt` into a stochastic key; the
    /// **identity** on every other mode. All public `sr_*` helpers
    /// delegate here with a domain-tagged salt.
    #[inline]
    pub const fn sr_derive(self, salt: u64) -> RoundingMode {
        match self {
            RoundingMode::StochasticRound(k) => RoundingMode::StochasticRound(sr_mix(k, salt)),
            other => other,
        }
    }

    /// Derive the key for SIMD/SWAR lane `i` of one packed operation.
    /// Identity for non-stochastic modes.
    #[inline]
    pub const fn sr_lane(self, i: u32) -> RoundingMode {
        self.sr_derive(SR_DOM_LANE ^ i as u64)
    }

    /// Derive the key for level `l` of a vsum reduction tree. Identity
    /// for non-stochastic modes.
    #[inline]
    pub const fn sr_level(self, l: u32) -> RoundingMode {
        self.sr_derive(SR_DOM_LEVEL ^ l as u64)
    }

    /// Derive the key for output/tensor element `e` (a flat index over
    /// the logical tensor, independent of blocking, packing or thread
    /// assignment). Identity for non-stochastic modes.
    #[inline]
    pub const fn sr_element(self, e: u64) -> RoundingMode {
        self.sr_derive(SR_DOM_ELEMENT ^ e)
    }

    /// Derive the key for accumulation step `s` (the k-index of a dot
    /// product's fold, again independent of blocking). Identity for
    /// non-stochastic modes.
    #[inline]
    pub const fn sr_step(self, s: u64) -> RoundingMode {
        self.sr_derive(SR_DOM_STEP ^ s)
    }

    /// Derive the key for accumulation sub-tree (chunk) `c` of a
    /// chunked fold. Identity for non-stochastic modes.
    #[inline]
    pub const fn sr_tree(self, c: u64) -> RoundingMode {
        self.sr_derive(SR_DOM_TREE ^ c)
    }

    /// Derive the key for inter-chunk combine `f` of a chunked fold.
    /// Identity for non-stochastic modes.
    #[inline]
    pub const fn sr_fold(self, f: u64) -> RoundingMode {
        self.sr_derive(SR_DOM_FOLD ^ f)
    }

    /// Derive the key for run `r` of a reused plan instance, so
    /// repeated executions draw fresh randomness while any single run
    /// stays a pure function of (seed, run index). Identity for
    /// non-stochastic modes.
    #[inline]
    pub const fn sr_run(self, r: u64) -> RoundingMode {
        self.sr_derive(SR_DOM_RUN ^ r)
    }

    /// Should the magnitude be incremented, given the rounding digits?
    ///
    /// * `sign` — sign of the value being rounded
    /// * `lsb` — least significant kept bit
    /// * `round` — first dropped bit
    /// * `sticky` — OR of all remaining dropped bits
    ///
    /// The stochastic mode answers as RNE here: [`round_pack`] never
    /// consults `increment` for it (the Bernoulli draw needs the full
    /// dropped fraction, not just round/sticky), so this arm only
    /// defines a sane nearest-style default for any out-of-tree caller.
    #[inline]
    pub fn increment(self, sign: bool, lsb: bool, round: bool, sticky: bool) -> bool {
        match self {
            RoundingMode::Rne | RoundingMode::StochasticRound(_) => round && (sticky || lsb),
            RoundingMode::Rtz => false,
            RoundingMode::Rdn => sign && (round || sticky),
            RoundingMode::Rup => !sign && (round || sticky),
            RoundingMode::Rmm => round,
        }
    }

    /// On overflow, does this mode saturate to max-finite instead of
    /// producing infinity (per IEEE 754 §4.3 directed-rounding rules)?
    /// Stochastic rounding overflows to infinity like the nearest
    /// modes.
    #[inline]
    pub fn overflow_to_max_finite(self, sign: bool) -> bool {
        match self {
            RoundingMode::Rne | RoundingMode::Rmm | RoundingMode::StochasticRound(_) => false,
            RoundingMode::Rtz => true,
            RoundingMode::Rdn => !sign, // +overflow stays at +maxfinite
            RoundingMode::Rup => sign,  // −overflow stays at −maxfinite
        }
    }
}

/// The uniform 32-bit draw for one stochastic rounding: the high half
/// of the key avalanched once more under its own domain tag (so the
/// draw is independent of every key derived *from* this key).
#[inline]
fn sr_draw32(key: u64) -> u64 {
    sr_mix(key, SR_DOM_DRAW) >> 32
}

/// The dropped fraction of an alignment shift, resolved to 32 bits:
/// `floor(dropped / 2^shift * 2^32)`, plus one if any nonzero residue
/// sits below that resolution (so a nonzero dropped part always has
/// probability ≥ 2^-32 and an exact midpoint is exactly `2^31`).
/// Returns a value in `[0, 2^32]`; rounding up fires iff the 32-bit
/// uniform draw is strictly below it.
#[inline]
fn sr_fraction(mant: u128, shift: u32, sticky: bool) -> u64 {
    debug_assert!(shift > 0, "sr_fraction needs a dropping shift");
    if shift >= 160 {
        // The whole 128-bit significand sits ≥ 2^32 below the grid:
        // below resolution, but nonzero.
        return 1;
    }
    let (hi, residue) = if shift > 127 {
        // Everything is dropped; the fraction is mant / 2^shift.
        (mant >> (shift - 32), (mant & ((1u128 << (shift - 32)) - 1)) != 0)
    } else {
        let dropped = mant & ((1u128 << shift) - 1);
        if shift >= 32 {
            (dropped >> (shift - 32), (dropped & ((1u128 << (shift - 32)) - 1)) != 0)
        } else {
            (dropped << (32 - shift), false)
        }
    };
    hi as u64 + (residue || sticky) as u64
}

/// Round and pack an exact finite nonzero-or-zero magnitude into `fmt`.
///
/// The input value is `(-1)^sign * (mant + ε) * 2^exp` where `mant` is an
/// unsigned significand of arbitrary position (not necessarily
/// normalized), and `ε ∈ (0,1)` is present iff `sticky` is set (bits
/// already discarded below the LSB weight of `mant`).
///
/// Handles normal/subnormal boundaries, overflow (to ±∞ or ±max-finite
/// depending on mode), and total underflow (to ±0 or the minimum
/// subnormal for directed modes). Under
/// [`RoundingMode::StochasticRound`] the increment decision is a seeded
/// Bernoulli draw on the dropped fraction instead of a nearest/directed
/// rule; exact values (nothing dropped, no sticky) are never perturbed.
///
/// `#[inline]`: the monomorphized fast tier calls this with a constant
/// format, folding the grid arithmetic per instantiation.
#[inline]
pub fn round_pack(sign: bool, exp: i32, mant: u128, sticky: bool, fmt: FpFormat, rm: RoundingMode) -> u64 {
    let sticky_in = sticky;
    if mant == 0 {
        if !sticky {
            return fmt.zero(sign);
        }
        // Magnitude is a pure sticky residue: strictly between 0 and one
        // LSB of whatever grid — rounds to zero except in directed modes
        // pointing away from zero (stochastically: with the minimum
        // representable probability, since the residue is below the
        // 32-bit fraction resolution).
        let inc = match rm {
            RoundingMode::StochasticRound(key) => sr_draw32(key) < 1,
            _ => rm.increment(sign, false, false, true),
        };
        return if inc {
            fmt.min_subnormal() | if sign { fmt.sign_mask() } else { 0 }
        } else {
            fmt.zero(sign)
        };
    }

    let man_bits = fmt.man_bits;
    let p = fmt.precision();
    let msb = 127 - mant.leading_zeros() as i32; // position of MSB within mant
    let e_msb = exp + msb; // value ∈ [2^e_msb, 2^(e_msb+1))

    // LSB weight of the destination grid: normal grid follows the MSB,
    // but never below the subnormal grid floor.
    let lsb_w_normal = e_msb - (p as i32 - 1);
    let lsb_w_floor = fmt.emin() - man_bits as i32;
    let lsb_w = lsb_w_normal.max(lsb_w_floor);

    // Align mant so that its LSB sits at lsb_w.
    let shift = lsb_w - exp;
    let (kept, round, sticky) = if shift <= 0 {
        // Exact: shift left (there is always room: kept has ≤ p bits).
        ((mant) << (-shift) as u32, false, sticky)
    } else if shift as u32 > 127 {
        (0u128, false, true) // everything dropped
    } else {
        let sh = shift as u32;
        let kept = mant >> sh;
        let dropped = mant & ((1u128 << sh) - 1);
        let round = (dropped >> (sh - 1)) & 1 == 1;
        let sticky_new = (dropped & ((1u128 << (sh - 1)) - 1)) != 0 || sticky;
        (kept, round, sticky_new)
    };

    let mut kept = kept;
    let mut lsb_w = lsb_w;
    let inc = match rm {
        RoundingMode::StochasticRound(key) => {
            if !round && !sticky {
                false // exact on the grid: never perturbed
            } else if shift <= 0 {
                // Only the incoming sticky residue was dropped — below
                // the fraction resolution, so minimum probability.
                sr_draw32(key) < 1
            } else {
                sr_draw32(key) < sr_fraction(mant, shift as u32, sticky_in)
            }
        }
        _ => rm.increment(sign, kept & 1 == 1, round, sticky),
    };
    if inc {
        kept += 1;
        if kept >> p != 0 {
            // Carry out of the significand: renormalize.
            kept >>= 1;
            lsb_w += 1;
        }
    }

    if kept == 0 {
        return fmt.zero(sign);
    }

    if kept >> man_bits == 0 {
        // Subnormal (LSB is pinned at the grid floor here by construction).
        debug_assert_eq!(lsb_w, lsb_w_floor);
        return fmt.assemble(sign, 0, kept as u64);
    }

    // Normal: kept has exactly p significant bits.
    debug_assert_eq!(kept >> man_bits, 1, "kept must be normalized to p bits");
    let e_res = lsb_w + man_bits as i32; // unbiased exponent
    if e_res > fmt.emax() {
        return if rm.overflow_to_max_finite(sign) {
            fmt.max_finite(sign)
        } else {
            fmt.infinity(sign)
        };
    }
    let exp_field = (e_res + fmt.bias()) as u64;
    fmt.assemble(sign, exp_field, (kept as u64) & fmt.man_mask())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8};

    #[test]
    fn exact_small_integers() {
        // 1.0 in FP32: mant=1, exp=0.
        assert_eq!(round_pack(false, 0, 1, false, FP32, RoundingMode::Rne), 0x3f80_0000);
        // 2.0
        assert_eq!(round_pack(false, 1, 1, false, FP32, RoundingMode::Rne), 0x4000_0000);
        // 3.0 = 11b * 2^0
        assert_eq!(round_pack(false, 0, 3, false, FP32, RoundingMode::Rne), 0x4040_0000);
        // -1.5 in FP16 = 1.1b
        assert_eq!(round_pack(true, -1, 3, false, FP16, RoundingMode::Rne), 0xbe00);
    }

    #[test]
    fn ties_to_even() {
        // FP8 (e5m2): 1.0 = 0x3c, next up 1.25 = 0x3d. 1.125 is a tie →
        // rounds to even (1.0).
        let tie = round_pack(false, -3, 9, false, FP8, RoundingMode::Rne); // 9/8
        assert_eq!(tie, 0x3c);
        // 1.375 ties to 1.5 (odd lsb → up to even).
        let tie2 = round_pack(false, -3, 11, false, FP8, RoundingMode::Rne); // 11/8
        assert_eq!(tie2, 0x3e);
        // A sticky bit breaks the tie upward.
        let no_tie = round_pack(false, -3, 9, true, FP8, RoundingMode::Rne);
        assert_eq!(no_tie, 0x3d);
    }

    #[test]
    fn directed_modes() {
        // 1 + tiny in FP32.
        let up = round_pack(false, 0, 1, true, FP32, RoundingMode::Rup);
        assert_eq!(up, 0x3f80_0001);
        let dn = round_pack(false, 0, 1, true, FP32, RoundingMode::Rdn);
        assert_eq!(dn, 0x3f80_0000);
        let tz = round_pack(false, 0, 1, true, FP32, RoundingMode::Rtz);
        assert_eq!(tz, 0x3f80_0000);
        // Negative: RDN moves away from zero.
        let ndn = round_pack(true, 0, 1, true, FP32, RoundingMode::Rdn);
        assert_eq!(ndn, 0xbf80_0001);
    }

    #[test]
    fn overflow_behaviour() {
        // 2^16 overflows FP16 (emax=15).
        let inf = round_pack(false, 16, 1, false, FP16, RoundingMode::Rne);
        assert_eq!(inf, FP16.infinity(false));
        let sat = round_pack(false, 16, 1, false, FP16, RoundingMode::Rtz);
        assert_eq!(sat, FP16.max_finite(false));
        let rdn_pos = round_pack(false, 16, 1, false, FP16, RoundingMode::Rdn);
        assert_eq!(rdn_pos, FP16.max_finite(false));
        let rdn_neg = round_pack(true, 16, 1, false, FP16, RoundingMode::Rdn);
        assert_eq!(rdn_neg, FP16.infinity(true));
    }

    #[test]
    fn subnormals() {
        // FP16 min subnormal = 2^-24.
        assert_eq!(round_pack(false, -24, 1, false, FP16, RoundingMode::Rne), 0x0001);
        // Half of it rounds to zero (tie to even).
        assert_eq!(round_pack(false, -25, 1, false, FP16, RoundingMode::Rne), 0x0000);
        // Slightly more than half rounds up.
        assert_eq!(round_pack(false, -25, 1, true, FP16, RoundingMode::Rne), 0x0001);
        // Largest subnormal: (2^10 - 1) * 2^-24.
        assert_eq!(round_pack(false, -24, 1023, false, FP16, RoundingMode::Rne), 0x03ff);
        // One ulp more is the smallest normal.
        assert_eq!(round_pack(false, -24, 1024, false, FP16, RoundingMode::Rne), 0x0400);
    }

    #[test]
    fn subnormal_rounds_up_to_normal() {
        // Largest subnormal + more than half ulp → min normal.
        assert_eq!(round_pack(false, -24, 1023, true, FP16, RoundingMode::Rup), 0x0400);
    }

    #[test]
    fn pure_sticky_underflow() {
        assert_eq!(round_pack(false, -1000, 0, true, FP16, RoundingMode::Rne), 0x0000);
        assert_eq!(round_pack(false, -1000, 0, true, FP16, RoundingMode::Rup), 0x0001);
        assert_eq!(round_pack(true, -1000, 0, true, FP16, RoundingMode::Rdn), 0x8001);
        assert_eq!(round_pack(true, -1000, 0, true, FP16, RoundingMode::Rup), 0x8000);
    }

    #[test]
    fn frm_roundtrip() {
        for rm in [
            RoundingMode::Rne,
            RoundingMode::Rtz,
            RoundingMode::Rdn,
            RoundingMode::Rup,
            RoundingMode::Rmm,
        ] {
            assert_eq!(RoundingMode::from_frm(rm.to_frm()), Some(rm));
        }
        assert_eq!(RoundingMode::from_frm(0b101), None);
        // The stochastic mode reports the reserved slot and (by design)
        // does not round-trip: the key cannot live in a 3-bit field.
        assert_eq!(RoundingMode::StochasticRound(7).to_frm(), 0b101);
    }

    // ------------------------------------------- stochastic rounding

    /// Keys used across the SR tests: element-derived from one session
    /// key, the way the batch engine derives them.
    fn sr_keys(n: u64) -> impl Iterator<Item = RoundingMode> {
        (0..n).map(|e| RoundingMode::StochasticRound(0xABCD_EF01).sr_element(e))
    }

    #[test]
    fn sr_helpers_are_identity_for_non_stochastic_modes() {
        for rm in [
            RoundingMode::Rne,
            RoundingMode::Rtz,
            RoundingMode::Rdn,
            RoundingMode::Rup,
            RoundingMode::Rmm,
        ] {
            assert_eq!(rm.sr_derive(123), rm);
            assert_eq!(rm.sr_lane(3).sr_level(2).sr_element(9).sr_step(4), rm);
            assert_eq!(rm.sr_tree(1).sr_fold(2).sr_run(7), rm);
            assert!(!rm.is_stochastic());
        }
        let sr = RoundingMode::StochasticRound(42);
        assert!(sr.is_stochastic());
        assert_ne!(sr.sr_lane(0), sr.sr_lane(1));
        assert_ne!(sr.sr_lane(3), sr.sr_level(3), "domain tags must separate index spaces");
        // Same derivation path, same key: determinism by construction.
        assert_eq!(sr.sr_element(5).sr_step(2), sr.sr_element(5).sr_step(2));
    }

    #[test]
    fn sr_is_deterministic_per_key() {
        for rm in sr_keys(64) {
            let a = round_pack(false, -3, 9, false, FP8, rm); // 1.125, a midpoint
            let b = round_pack(false, -3, 9, false, FP8, rm);
            assert_eq!(a, b, "same key must round the same way");
            assert!(a == 0x3c || a == 0x3d, "midpoint must land on a neighbor, got {a:#x}");
        }
    }

    #[test]
    fn sr_never_perturbs_exact_values() {
        for rm in sr_keys(256) {
            // 1.0 and -1.5 are exact in every tested format.
            assert_eq!(round_pack(false, 0, 1, false, FP32, rm), 0x3f80_0000);
            assert_eq!(round_pack(true, -1, 3, false, FP16, rm), 0xbe00);
            assert_eq!(round_pack(false, -3, 8, false, FP8, rm), 0x3c); // 1.0 = 8/8
            assert_eq!(round_pack(false, 0, 0, false, FP8, rm), FP8.zero(false));
        }
    }

    /// Seeded statistical unbiasedness: over many derived keys, an
    /// exact midpoint (dropped fraction 1/2) must round up almost
    /// exactly half the time, and the mean of the rounded values must
    /// converge to the exact value. Everything is derived from fixed
    /// seeds, so the counts are deterministic — the bounds cannot
    /// flake.
    #[test]
    fn sr_midpoint_is_unbiased() {
        let n = 4096u64;
        let mut ups = 0u64;
        let mut mean = 0.0f64;
        for rm in sr_keys(n) {
            // 1.125 in FP8 e5m2: exactly between 1.0 (0x3c) and 1.25
            // (0x3d).
            let r = round_pack(false, -3, 9, false, FP8, rm);
            if r == 0x3d {
                ups += 1;
            } else {
                assert_eq!(r, 0x3c);
            }
            mean += crate::softfloat::to_f64(r, FP8) / n as f64;
        }
        // Binomial(4096, 1/2): |ups - 2048| < 256 is > 15 sigma — and
        // the draw is seeded, so this is a fixed number, not a sample.
        let dev = ups.abs_diff(n / 2);
        assert!(dev < 256, "midpoint rounded up {ups}/{n} times");
        // E[rounded] = 1.125 exactly; the seeded mean must sit within
        // the same deviation bound scaled by the 0.25 step.
        let err = (mean - 1.125).abs();
        assert!(err < 256.0 / n as f64 * 0.25, "seeded mean {mean} drifted from 1.125");
        // RNE on the same midpoint is deterministic — all-down here —
        // which is exactly the bias SR removes.
        assert_eq!(round_pack(false, -3, 9, false, FP8, RoundingMode::Rne), 0x3c);
    }

    /// A quarter-fraction value must round up about a quarter of the
    /// time: the probability tracks the dropped fraction, not just 1/2
    /// at midpoints.
    #[test]
    fn sr_probability_tracks_the_dropped_fraction() {
        let n = 4096u64;
        let mut ups = 0u64;
        for rm in sr_keys(n) {
            // 1.0625 = 17/16 in FP8: dropped fraction 1/4 of an ulp.
            let r = round_pack(false, -4, 17, false, FP8, rm);
            if r == 0x3d {
                ups += 1;
            } else {
                assert_eq!(r, 0x3c);
            }
        }
        let dev = ups.abs_diff(n / 4);
        assert!(dev < 256, "quarter-fraction rounded up {ups}/{n} times");
    }

    #[test]
    fn sr_fraction_resolution() {
        // Exact midpoint at a 1-bit shift: fraction is exactly 2^31.
        assert_eq!(sr_fraction(1, 1, false), 1u64 << 31);
        // Exact midpoint at a wide shift.
        assert_eq!(sr_fraction(1u128 << 63, 64, false), 1u64 << 31);
        // A nonzero residue below resolution still has probability 1.
        assert_eq!(sr_fraction(1, 64, false), 1);
        assert_eq!(sr_fraction(0, 1, true), 1);
        // Sticky bumps an otherwise-exact fraction by one step.
        assert_eq!(sr_fraction(1, 1, true), (1u64 << 31) + 1);
        // Just below a full ulp saturates at 2^32 (always rounds up).
        assert_eq!(sr_fraction(u32::MAX as u128, 32, true), 1u64 << 32);
    }

    #[test]
    fn sr_overflow_goes_to_infinity() {
        for rm in sr_keys(16) {
            assert_eq!(round_pack(false, 16, 1, false, FP16, rm), FP16.infinity(false));
            assert_eq!(round_pack(true, 16, 1, false, FP16, rm), FP16.infinity(true));
        }
    }
}
