//! The single shared rounding/packing step.
//!
//! All emulated units — scalar FPU ops, the ExFMA cascade baseline, and
//! the fused ExSdotp datapath — terminate in [`round_pack`]: an exact
//! (significand, exponent, sticky) triple is rounded once into a target
//! [`FpFormat`]. Centralizing this guarantees that accuracy differences
//! measured in Table IV come from the *datapath* (one rounding vs. two),
//! not from inconsistent rounding implementations.

use crate::formats::FpFormat;

/// RISC-V `frm` rounding modes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RoundingMode {
    /// Round to nearest, ties to even (`frm=000`).
    Rne,
    /// Round towards zero (`frm=001`).
    Rtz,
    /// Round down, towards −∞ (`frm=010`).
    Rdn,
    /// Round up, towards +∞ (`frm=011`).
    Rup,
    /// Round to nearest, ties to max magnitude (`frm=100`).
    Rmm,
}

impl RoundingMode {
    /// RISC-V `frm` encoding.
    pub const fn to_frm(self) -> u32 {
        match self {
            RoundingMode::Rne => 0b000,
            RoundingMode::Rtz => 0b001,
            RoundingMode::Rdn => 0b010,
            RoundingMode::Rup => 0b011,
            RoundingMode::Rmm => 0b100,
        }
    }

    /// Decode a RISC-V `frm` field.
    pub const fn from_frm(frm: u32) -> Option<Self> {
        match frm {
            0b000 => Some(RoundingMode::Rne),
            0b001 => Some(RoundingMode::Rtz),
            0b010 => Some(RoundingMode::Rdn),
            0b011 => Some(RoundingMode::Rup),
            0b100 => Some(RoundingMode::Rmm),
            _ => None,
        }
    }

    /// Should the magnitude be incremented, given the rounding digits?
    ///
    /// * `sign` — sign of the value being rounded
    /// * `lsb` — least significant kept bit
    /// * `round` — first dropped bit
    /// * `sticky` — OR of all remaining dropped bits
    #[inline]
    pub fn increment(self, sign: bool, lsb: bool, round: bool, sticky: bool) -> bool {
        match self {
            RoundingMode::Rne => round && (sticky || lsb),
            RoundingMode::Rtz => false,
            RoundingMode::Rdn => sign && (round || sticky),
            RoundingMode::Rup => !sign && (round || sticky),
            RoundingMode::Rmm => round,
        }
    }

    /// On overflow, does this mode saturate to max-finite instead of
    /// producing infinity (per IEEE 754 §4.3 directed-rounding rules)?
    #[inline]
    pub fn overflow_to_max_finite(self, sign: bool) -> bool {
        match self {
            RoundingMode::Rne | RoundingMode::Rmm => false,
            RoundingMode::Rtz => true,
            RoundingMode::Rdn => !sign, // +overflow stays at +maxfinite
            RoundingMode::Rup => sign,  // −overflow stays at −maxfinite
        }
    }
}

/// Round and pack an exact finite nonzero-or-zero magnitude into `fmt`.
///
/// The input value is `(-1)^sign * (mant + ε) * 2^exp` where `mant` is an
/// unsigned significand of arbitrary position (not necessarily
/// normalized), and `ε ∈ (0,1)` is present iff `sticky` is set (bits
/// already discarded below the LSB weight of `mant`).
///
/// Handles normal/subnormal boundaries, overflow (to ±∞ or ±max-finite
/// depending on mode), and total underflow (to ±0 or the minimum
/// subnormal for directed modes).
///
/// `#[inline]`: the monomorphized fast tier calls this with a constant
/// format, folding the grid arithmetic per instantiation.
#[inline]
pub fn round_pack(sign: bool, exp: i32, mant: u128, sticky: bool, fmt: FpFormat, rm: RoundingMode) -> u64 {
    if mant == 0 {
        if !sticky {
            return fmt.zero(sign);
        }
        // Magnitude is a pure sticky residue: strictly between 0 and one
        // LSB of whatever grid — rounds to zero except in directed modes
        // pointing away from zero.
        return if rm.increment(sign, false, false, true) {
            fmt.min_subnormal() | if sign { fmt.sign_mask() } else { 0 }
        } else {
            fmt.zero(sign)
        };
    }

    let man_bits = fmt.man_bits;
    let p = fmt.precision();
    let msb = 127 - mant.leading_zeros() as i32; // position of MSB within mant
    let e_msb = exp + msb; // value ∈ [2^e_msb, 2^(e_msb+1))

    // LSB weight of the destination grid: normal grid follows the MSB,
    // but never below the subnormal grid floor.
    let lsb_w_normal = e_msb - (p as i32 - 1);
    let lsb_w_floor = fmt.emin() - man_bits as i32;
    let lsb_w = lsb_w_normal.max(lsb_w_floor);

    // Align mant so that its LSB sits at lsb_w.
    let shift = lsb_w - exp;
    let (kept, round, sticky) = if shift <= 0 {
        // Exact: shift left (there is always room: kept has ≤ p bits).
        ((mant) << (-shift) as u32, false, sticky)
    } else if shift as u32 > 127 {
        (0u128, false, true) // everything dropped
    } else {
        let sh = shift as u32;
        let kept = mant >> sh;
        let dropped = mant & ((1u128 << sh) - 1);
        let round = (dropped >> (sh - 1)) & 1 == 1;
        let sticky_new = (dropped & ((1u128 << (sh - 1)) - 1)) != 0 || sticky;
        (kept, round, sticky_new)
    };

    let mut kept = kept;
    let mut lsb_w = lsb_w;
    if rm.increment(sign, kept & 1 == 1, round, sticky) {
        kept += 1;
        if kept >> p != 0 {
            // Carry out of the significand: renormalize.
            kept >>= 1;
            lsb_w += 1;
        }
    }

    if kept == 0 {
        return fmt.zero(sign);
    }

    if kept >> man_bits == 0 {
        // Subnormal (LSB is pinned at the grid floor here by construction).
        debug_assert_eq!(lsb_w, lsb_w_floor);
        return fmt.assemble(sign, 0, kept as u64);
    }

    // Normal: kept has exactly p significant bits.
    debug_assert_eq!(kept >> man_bits, 1, "kept must be normalized to p bits");
    let e_res = lsb_w + man_bits as i32; // unbiased exponent
    if e_res > fmt.emax() {
        return if rm.overflow_to_max_finite(sign) {
            fmt.max_finite(sign)
        } else {
            fmt.infinity(sign)
        };
    }
    let exp_field = (e_res + fmt.bias()) as u64;
    fmt.assemble(sign, exp_field, (kept as u64) & fmt.man_mask())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8};

    #[test]
    fn exact_small_integers() {
        // 1.0 in FP32: mant=1, exp=0.
        assert_eq!(round_pack(false, 0, 1, false, FP32, RoundingMode::Rne), 0x3f80_0000);
        // 2.0
        assert_eq!(round_pack(false, 1, 1, false, FP32, RoundingMode::Rne), 0x4000_0000);
        // 3.0 = 11b * 2^0
        assert_eq!(round_pack(false, 0, 3, false, FP32, RoundingMode::Rne), 0x4040_0000);
        // -1.5 in FP16 = 1.1b
        assert_eq!(round_pack(true, -1, 3, false, FP16, RoundingMode::Rne), 0xbe00);
    }

    #[test]
    fn ties_to_even() {
        // FP8 (e5m2): 1.0 = 0x3c, next up 1.25 = 0x3d. 1.125 is a tie →
        // rounds to even (1.0).
        let tie = round_pack(false, -3, 9, false, FP8, RoundingMode::Rne); // 9/8
        assert_eq!(tie, 0x3c);
        // 1.375 ties to 1.5 (odd lsb → up to even).
        let tie2 = round_pack(false, -3, 11, false, FP8, RoundingMode::Rne); // 11/8
        assert_eq!(tie2, 0x3e);
        // A sticky bit breaks the tie upward.
        let no_tie = round_pack(false, -3, 9, true, FP8, RoundingMode::Rne);
        assert_eq!(no_tie, 0x3d);
    }

    #[test]
    fn directed_modes() {
        // 1 + tiny in FP32.
        let up = round_pack(false, 0, 1, true, FP32, RoundingMode::Rup);
        assert_eq!(up, 0x3f80_0001);
        let dn = round_pack(false, 0, 1, true, FP32, RoundingMode::Rdn);
        assert_eq!(dn, 0x3f80_0000);
        let tz = round_pack(false, 0, 1, true, FP32, RoundingMode::Rtz);
        assert_eq!(tz, 0x3f80_0000);
        // Negative: RDN moves away from zero.
        let ndn = round_pack(true, 0, 1, true, FP32, RoundingMode::Rdn);
        assert_eq!(ndn, 0xbf80_0001);
    }

    #[test]
    fn overflow_behaviour() {
        // 2^16 overflows FP16 (emax=15).
        let inf = round_pack(false, 16, 1, false, FP16, RoundingMode::Rne);
        assert_eq!(inf, FP16.infinity(false));
        let sat = round_pack(false, 16, 1, false, FP16, RoundingMode::Rtz);
        assert_eq!(sat, FP16.max_finite(false));
        let rdn_pos = round_pack(false, 16, 1, false, FP16, RoundingMode::Rdn);
        assert_eq!(rdn_pos, FP16.max_finite(false));
        let rdn_neg = round_pack(true, 16, 1, false, FP16, RoundingMode::Rdn);
        assert_eq!(rdn_neg, FP16.infinity(true));
    }

    #[test]
    fn subnormals() {
        // FP16 min subnormal = 2^-24.
        assert_eq!(round_pack(false, -24, 1, false, FP16, RoundingMode::Rne), 0x0001);
        // Half of it rounds to zero (tie to even).
        assert_eq!(round_pack(false, -25, 1, false, FP16, RoundingMode::Rne), 0x0000);
        // Slightly more than half rounds up.
        assert_eq!(round_pack(false, -25, 1, true, FP16, RoundingMode::Rne), 0x0001);
        // Largest subnormal: (2^10 - 1) * 2^-24.
        assert_eq!(round_pack(false, -24, 1023, false, FP16, RoundingMode::Rne), 0x03ff);
        // One ulp more is the smallest normal.
        assert_eq!(round_pack(false, -24, 1024, false, FP16, RoundingMode::Rne), 0x0400);
    }

    #[test]
    fn subnormal_rounds_up_to_normal() {
        // Largest subnormal + more than half ulp → min normal.
        assert_eq!(round_pack(false, -24, 1023, true, FP16, RoundingMode::Rup), 0x0400);
    }

    #[test]
    fn pure_sticky_underflow() {
        assert_eq!(round_pack(false, -1000, 0, true, FP16, RoundingMode::Rne), 0x0000);
        assert_eq!(round_pack(false, -1000, 0, true, FP16, RoundingMode::Rup), 0x0001);
        assert_eq!(round_pack(true, -1000, 0, true, FP16, RoundingMode::Rdn), 0x8001);
        assert_eq!(round_pack(true, -1000, 0, true, FP16, RoundingMode::Rup), 0x8000);
    }

    #[test]
    fn frm_roundtrip() {
        for rm in [
            RoundingMode::Rne,
            RoundingMode::Rtz,
            RoundingMode::Rdn,
            RoundingMode::Rup,
            RoundingMode::Rmm,
        ] {
            assert_eq!(RoundingMode::from_frm(rm.to_frm()), Some(rm));
        }
        assert_eq!(RoundingMode::from_frm(0b101), None);
    }
}
