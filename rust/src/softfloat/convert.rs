//! Bridges between host `f64` and arbitrary-format encodings.
//!
//! `to_f64` is exact for every format up to 64 bits wide (FP64's
//! significand and exponent range dominate all of them); `from_f64`
//! performs a single correct rounding into the target format. These are
//! the I/O boundary of the emulation — used to initialize matrices and
//! read back results, never inside an emulated datapath.

use super::ops::cast;
use super::round::RoundingMode;
use crate::formats::{FpFormat, FP64};

/// Decode `bits` (format `fmt`) to the exactly equal `f64`.
///
/// Exact because every FP8/FP16/FP32 value is representable in FP64
/// (widening casts are exact).
#[inline]
pub fn to_f64(bits: u64, fmt: FpFormat) -> f64 {
    if fmt == FP64 {
        return f64::from_bits(bits);
    }
    f64::from_bits(cast(fmt, FP64, bits, RoundingMode::Rne))
}

/// Encode `x` into `fmt` with one correct rounding in mode `rm`.
#[inline]
pub fn from_f64(x: f64, fmt: FpFormat, rm: RoundingMode) -> u64 {
    if fmt == FP64 {
        return x.to_bits();
    }
    cast(FP64, fmt, x.to_bits(), rm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP16, FP32, FP8, FP8ALT, PAPER_FORMATS};

    #[test]
    fn f64_roundtrip_exact_for_all_narrow_encodings() {
        // Every finite narrow encoding → f64 → back must be the identity.
        for fmt in PAPER_FORMATS {
            if fmt.width() > 16 {
                continue;
            }
            for bits in 0..(1u64 << fmt.width()) {
                if fmt.is_nan(bits) {
                    continue;
                }
                let x = to_f64(bits, fmt);
                let back = from_f64(x, fmt, RoundingMode::Rne);
                assert_eq!(back, bits, "fmt={} bits={bits:#x} x={x}", fmt.name());
            }
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(to_f64(0x3c00, FP16), 1.0);
        assert_eq!(to_f64(0xc000, FP16), -2.0);
        assert_eq!(to_f64(0x3c, FP8), 1.0); // e5m2: 0 01111 00
        assert_eq!(to_f64(0x38, FP8ALT), 1.0); // e4m3: 0 0111 000
        assert_eq!(from_f64(1.5, FP32, RoundingMode::Rne), 0x3fc0_0000);
        // FP8 max finite = 1.75 * 2^15 = 57344.
        assert_eq!(to_f64(FP8.max_finite(false), FP8), 57344.0);
        // FP8alt max finite = 1.875 * 2^7 = 240.
        assert_eq!(to_f64(FP8ALT.max_finite(false), FP8ALT), 240.0);
        // FP16 min subnormal = 2^-24.
        assert_eq!(to_f64(1, FP16), 2.0_f64.powi(-24));
    }

    #[test]
    fn f32_agrees_with_native() {
        for x in [0.0f32, -0.0, 1.0, -1.5, 3.141592653589793, 1e-40, f32::MAX, f32::MIN_POSITIVE, 1e38] {
            assert_eq!(to_f64(x.to_bits() as u64, FP32), x as f64);
            assert_eq!(from_f64(x as f64, FP32, RoundingMode::Rne), x.to_bits() as u64);
        }
    }

    #[test]
    fn rounding_into_narrow_formats() {
        // 1.1 is not representable in FP8 (e5m2): nearest values are 1.0
        // and 1.25 → RNE picks 1.0.
        assert_eq!(to_f64(from_f64(1.1, FP8, RoundingMode::Rne), FP8), 1.0);
        assert_eq!(to_f64(from_f64(1.1, FP8, RoundingMode::Rup), FP8), 1.25);
        // Overflow saturates or goes to inf by mode.
        assert_eq!(from_f64(1e6, FP8, RoundingMode::Rne), FP8.infinity(false));
        assert_eq!(from_f64(1e6, FP8, RoundingMode::Rtz), FP8.max_finite(false));
    }
}
