//! Bit-accurate software floating-point for arbitrary [`FpFormat`]s.
//!
//! This is the numerical substrate of the reproduction: every FPU
//! operation the MiniFloat-NN PE executes (§III) is emulated here with
//! full IEEE-754 semantics — subnormals, signed zeros, infinities, NaN
//! propagation, and all five RISC-V rounding modes.
//!
//! Operations are *single-rounded*: internal computation is exact (wide
//! integer significands + sticky bits) and rounding happens once at the
//! end, exactly like the hardware units they model. The expanding FMA
//! ([`ops::ex_fma`]) multiplies in a narrow source format and
//! adds/rounds in a wider destination format, mirroring the ExFMA units
//! of FPnew that the paper uses as its baseline (§II-B).
//!
//! The ExSdotp *fused* three-term datapath lives in [`crate::exsdotp`];
//! it shares [`round::round_pack`] with this module so the two rounding
//! behaviours (once vs. twice) can be compared apples-to-apples, which
//! is precisely the paper's Table IV experiment.
//!
//! Two dispatch tiers expose the same numerics:
//!
//! * the functions in this module take a runtime [`FpFormat`] — the
//!   flexible descriptor API every simulator layer uses;
//! * [`fast`] provides monomorphized twins (`add_m::<Fp16>`, …) that
//!   call the *same* implementations with compile-time formats, for the
//!   batch engine's hot loops ([`crate::batch`]).
//!
//! A third, register-level layer — [`swar`] — treats a packed `u64` as
//! all of a format's SIMD lanes at once: bit-plane field extraction and
//! branch-free special-lane classification, feeding the SWAR ExSdotp
//! kernels in [`crate::exsdotp::swar`]. It adds no third numerics
//! implementation: special registers route back to the scalar tier and
//! finite lanes terminate in the same [`round::round_pack`].

pub mod convert;
pub mod fast;
pub mod ops;
pub mod round;
pub mod swar;
#[cfg(test)]
mod tests;
pub mod unpack;

pub use convert::{from_f64, to_f64};
pub use ops::{add, cast, cmp, ex_fma, fma, max, min, mul, sub, FpClass};
pub use round::{round_pack, RoundingMode};
pub use unpack::{unpack, Class, Unpacked};

use crate::formats::FpFormat;

/// Convenience handle binding a format to the free-function API.
///
/// ```no_run
/// use minifloat_nn::{SoftFloat, RoundingMode, FP16};
/// let sf = SoftFloat::new(FP16);
/// let one = sf.from_f64(1.0);
/// let two = sf.add(one, one, RoundingMode::Rne);
/// assert_eq!(sf.to_f64(two), 2.0);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct SoftFloat {
    /// The bound format.
    pub fmt: FpFormat,
}

impl SoftFloat {
    /// Bind a format.
    pub const fn new(fmt: FpFormat) -> Self {
        Self { fmt }
    }

    /// Encode an `f64` into this format (correctly rounded, RNE).
    pub fn from_f64(&self, x: f64) -> u64 {
        convert::from_f64(x, self.fmt, RoundingMode::Rne)
    }

    /// Decode to `f64` (exact for all formats up to FP64).
    pub fn to_f64(&self, bits: u64) -> f64 {
        convert::to_f64(bits, self.fmt)
    }

    /// IEEE addition.
    pub fn add(&self, a: u64, b: u64, rm: RoundingMode) -> u64 {
        ops::add(self.fmt, a, b, rm)
    }

    /// IEEE subtraction.
    pub fn sub(&self, a: u64, b: u64, rm: RoundingMode) -> u64 {
        ops::sub(self.fmt, a, b, rm)
    }

    /// IEEE multiplication.
    pub fn mul(&self, a: u64, b: u64, rm: RoundingMode) -> u64 {
        ops::mul(self.fmt, a, b, rm)
    }

    /// Fused multiply-add `a*b + c`, single rounding.
    pub fn fma(&self, a: u64, b: u64, c: u64, rm: RoundingMode) -> u64 {
        ops::fma(self.fmt, a, b, c, rm)
    }
}
