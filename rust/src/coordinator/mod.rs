//! L3 coordinator: the **artifact-backed** (PJRT) training driver.
//!
//! The paper's contribution lives at the ISA/FPU level, so the
//! coordinator is deliberately thin (per the architecture): it owns the
//! process lifecycle, the dataset, the batch loop and the metrics, and
//! drives the AOT-compiled HFP8 training artifacts through the PJRT
//! runtime. Python authored the compute graph once, at build time; all
//! of training runs from this Rust loop.
//!
//! Offline builds have no PJRT backend, so this engine is the
//! *fallback* (`repro train --engine pjrt`); the default training path
//! is the native subsystem ([`crate::nn`], via
//! [`crate::api::Session::train`]), which needs no artifacts and routes
//! every matmul through the minifloat batch engine.

pub mod data;

use crate::runtime::{Executable, Runtime, Tensor};
use crate::util::error::{Context, Result};
use data::SpiralDataset;

/// Model shape constants — must match `python/compile/model.py`
/// (artifacts are shape-specialized; mismatches fail at execute time).
pub mod shape {
    /// Batch size compiled into the artifacts.
    pub const BATCH: usize = 64;
    /// Input embedding width.
    pub const FEATURES: usize = 4;
    /// Hidden width.
    pub const HIDDEN: usize = 32;
    /// Output classes (3 spiral arms + padding).
    pub const CLASSES: usize = 4;
}

/// Which training-step artifact to drive.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Precision {
    /// HFP8 mixed-precision (FP8alt forward / FP8 backward, FP16 acc).
    Hfp8,
    /// The f32 baseline.
    Fp32,
}

impl Precision {
    fn artifact(&self) -> &'static str {
        match self {
            Precision::Hfp8 => "train_step_hfp8",
            Precision::Fp32 => "train_step_fp32",
        }
    }
}

/// Model parameters as runtime tensors (f32 master copies).
pub struct Params {
    tensors: Vec<Tensor>, // w1 b1 w2 b2 w3 b3
}

impl Params {
    /// He-style init from a seed (mirrors `model.init_params`).
    pub fn init(seed: u64) -> Self {
        use shape::*;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut dense = |m: usize, n: usize| -> Tensor {
            let scale = (2.0 / m as f64).sqrt();
            Tensor::new((0..m * n).map(|_| (rng.gaussian() * scale) as f32).collect(), &[m, n])
        };
        let w1 = dense(FEATURES, HIDDEN);
        let w2 = dense(HIDDEN, HIDDEN);
        let w3 = dense(HIDDEN, CLASSES);
        Params {
            tensors: vec![
                w1,
                Tensor::zeros(&[HIDDEN]),
                w2,
                Tensor::zeros(&[HIDDEN]),
                w3,
                Tensor::zeros(&[CLASSES]),
            ],
        }
    }
}

/// Per-step record for the loss curve (EXPERIMENTS.md E2E).
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    /// Step index.
    pub step: usize,
    /// Training loss after the step.
    pub loss: f32,
}

/// The training coordinator.
pub struct Trainer {
    step_exe: Executable,
    predict_exe: Executable,
    params: Params,
    dataset: SpiralDataset,
    rng: crate::util::rng::Rng,
    /// Loss history.
    pub history: Vec<StepLog>,
}

impl Trainer {
    /// Load artifacts and set up the run.
    pub fn new(artifacts_dir: &str, precision: Precision, seed: u64) -> Result<Self> {
        let rt = Runtime::cpu().context("creating PJRT CPU client")?;
        let step_exe = rt
            .load_artifact(artifacts_dir, precision.artifact())
            .with_context(|| format!("loading {} (run `make artifacts`)", precision.artifact()))?;
        let predict_exe = rt.load_artifact(artifacts_dir, "predict_hfp8")?;
        Ok(Trainer {
            step_exe,
            predict_exe,
            params: Params::init(seed),
            dataset: SpiralDataset::generate(300, seed ^ 0xD47A),
            rng: crate::util::rng::Rng::new(seed ^ 0x5339),
            history: Vec::new(),
        })
    }

    /// Run one SGD step on a random batch; returns the loss.
    pub fn step(&mut self) -> Result<f32> {
        let (x, y) = self.dataset.batch(shape::BATCH, &mut self.rng);
        let mut inputs = self.params.tensors.clone();
        inputs.push(x);
        inputs.push(y);
        let mut out = self.step_exe.run(&inputs)?;
        crate::ensure!(out.len() == 7, "train_step returns 6 params + loss, got {}", out.len());
        let loss = out.pop().unwrap().data[0];
        self.params.tensors = out;
        let step = self.history.len();
        self.history.push(StepLog { step, loss });
        Ok(loss)
    }

    /// Train for `steps` batches; returns the final loss.
    pub fn train(&mut self, steps: usize, log_every: usize) -> Result<f32> {
        let mut last = f32::NAN;
        for i in 0..steps {
            last = self.step()?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                println!("step {i:>4}  loss {last:.4}");
            }
        }
        Ok(last)
    }

    /// Classification accuracy over the whole dataset (HFP8 forward).
    pub fn accuracy(&mut self) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let n = self.dataset.len();
        let mut idx = 0;
        while idx + shape::BATCH <= n {
            let (x, labels) = self.dataset.ordered_batch(idx, shape::BATCH);
            let mut inputs = self.params.tensors.clone();
            inputs.push(x);
            let out = self.predict_exe.run(&inputs)?;
            let logits = &out[0];
            for (b, &label) in labels.iter().enumerate() {
                let row = &logits.data[b * shape::CLASSES..(b + 1) * shape::CLASSES];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                correct += (pred == label as usize) as usize;
                total += 1;
            }
            idx += shape::BATCH;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Mean loss over the most recent `n` steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        tail.iter().map(|s| s.loss).sum::<f32>() / tail.len().max(1) as f32
    }
}
