//! Synthetic three-arm spiral dataset (the classic toy classification
//! workload) with the same embedding as `python/compile/model.py`.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// Spiral points with labels, pre-embedded into the model's input space.
pub struct SpiralDataset {
    /// Embedded features, row-major (n × FEATURES).
    pub x: Vec<[f32; 4]>,
    /// Class labels (0..3).
    pub y: Vec<u8>,
}

impl SpiralDataset {
    /// Generate `n_per_class` points per arm (3 arms).
    pub fn generate(n_per_class: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(3 * n_per_class);
        let mut y = Vec::with_capacity(3 * n_per_class);
        for class in 0..3u8 {
            for i in 0..n_per_class {
                let t = 0.1 + 0.9 * (i as f64 / (n_per_class - 1).max(1) as f64);
                let theta = t * 4.5 + class as f64 * 2.1 + rng.gaussian() * 0.1;
                let r = t;
                let (px, py) = (r * theta.cos(), r * theta.sin());
                x.push(Self::embed(px as f32, py as f32));
                y.push(class);
            }
        }
        // Shuffle (deterministic).
        for i in (1..x.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            x.swap(i, j);
            y.swap(i, j);
        }
        SpiralDataset { x, y }
    }

    /// The (x, y, r², 1) embedding (matches `model.embed`).
    pub fn embed(px: f32, py: f32) -> [f32; 4] {
        [px, py, px * px + py * py, 1.0]
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.x.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Random batch as (features, one-hot labels) tensors.
    pub fn batch(&self, size: usize, rng: &mut Rng) -> (Tensor, Tensor) {
        let mut xs = Vec::with_capacity(size * 4);
        let mut ys = vec![0f32; size * 4];
        for b in 0..size {
            let i = rng.below(self.x.len() as u64) as usize;
            xs.extend_from_slice(&self.x[i]);
            ys[b * 4 + self.y[i] as usize] = 1.0;
        }
        (Tensor::new(xs, &[size, 4]), Tensor::new(ys, &[size, 4]))
    }

    /// Sequential batch starting at `start` (for evaluation sweeps);
    /// returns raw labels.
    pub fn ordered_batch(&self, start: usize, size: usize) -> (Tensor, Vec<u8>) {
        let mut xs = Vec::with_capacity(size * 4);
        let mut labels = Vec::with_capacity(size);
        for b in 0..size {
            let i = (start + b) % self.x.len();
            xs.extend_from_slice(&self.x[i]);
            labels.push(self.y[i]);
        }
        (Tensor::new(xs, &[size, 4]), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_balanced_classes() {
        let d = SpiralDataset::generate(50, 1);
        assert_eq!(d.len(), 150);
        for c in 0..3u8 {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 50);
        }
    }

    #[test]
    fn batches_have_one_hot_labels() {
        let d = SpiralDataset::generate(50, 2);
        let mut rng = Rng::new(3);
        let (x, y) = d.batch(16, &mut rng);
        assert_eq!(x.shape, vec![16, 4]);
        assert_eq!(y.shape, vec![16, 4]);
        for b in 0..16 {
            let row = &y.data[b * 4..(b + 1) * 4];
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = SpiralDataset::generate(20, 9);
        let b = SpiralDataset::generate(20, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
