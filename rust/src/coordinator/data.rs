//! Re-export shim: [`SpiralDataset`] moved to [`crate::nn::data`] when
//! the native training subsystem generalized the dataset layer (it owns
//! the padded [`crate::nn::data::Dataset`] form too). This path stays so
//! the PJRT coordinator and downstream imports keep compiling; new code
//! should import from `nn::data`.

pub use crate::nn::data::SpiralDataset;
