//! Accuracy-at-scale numerics differentials
//! (`cargo test --test numerics_differential`).
//!
//! Two gate families for the `numerics` subsystem:
//!
//! * **Stochastic rounding is seeded, not noisy.** An SR run is a pure
//!   function of `(seed, element index)` — the same plan must produce
//!   bit-identical results across thread budgets, lane tiers, and
//!   executor backends, and two sessions built from the same seed must
//!   agree while different seeds (and RNE) must not.
//! * **Chunked accumulation tightens big-K error without forking the
//!   semantics.** At K = 4096 an FP8→FP16 GEMM with a 256-element
//!   chunk tree must be at least as close to the f64 reference (taken
//!   over the *quantized* operands, isolating accumulation error) as
//!   the naive left-to-right fold, and `chunk_k(K)` must degenerate to
//!   the naive fold bit-for-bit — under RNE and under SR.

use minifloat_nn::batch::{with_lane_tier, LaneTier};
use minifloat_nn::prelude::*;
use minifloat_nn::util::parallel::{with_dispatch, Dispatch};

fn gaussian_mats(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = minifloat_nn::util::rng::Rng::new(seed);
    let a = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    (a, b)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run one FP8→FP16 GEMM on a fresh SR session and return result bits.
fn sr_gemm_bits(
    seed: u64,
    threads: usize,
    chunk: Option<usize>,
    (m, n, k): (usize, usize, usize),
    a: &[f64],
    b: &[f64],
) -> Vec<u64> {
    let session = Session::builder().seed(seed).threads(threads).stochastic_rounding().build();
    let mut plan = session.gemm().src(FP8).acc(FP16);
    if let Some(c) = chunk {
        plan = plan.chunk_k(c);
    }
    let run = plan.dims(m, n, k).expect("plan").run_f64(a, b).expect("run");
    bits(&run.c_f64())
}

// ------------------------------------------------- SR bit-determinism

#[test]
fn sr_is_bit_identical_across_threads_tiers_and_dispatchers() {
    let dims = (16, 16, 512);
    let (a, b) = gaussian_mats(dims.0, dims.1, dims.2, 0xD1FF);
    // Reference: serial dispatch, default SWAR tier, one worker.
    let reference = with_dispatch(Dispatch::Serial, || {
        with_lane_tier(LaneTier::Swar, || sr_gemm_bits(42, 1, Some(128), dims, &a, &b))
    });
    for tier in [LaneTier::Swar, LaneTier::Scalar] {
        for disp in [Dispatch::Pool, Dispatch::Scoped, Dispatch::Serial] {
            for threads in [1usize, 4, 7] {
                let got = with_dispatch(disp, || {
                    with_lane_tier(tier, || sr_gemm_bits(42, threads, Some(128), dims, &a, &b))
                });
                assert_eq!(
                    got, reference,
                    "{tier:?}/{disp:?}/threads={threads}: SR result drifted from the \
                     serial single-worker reference"
                );
            }
        }
    }
}

#[test]
fn sr_is_a_pure_function_of_the_seed() {
    let dims = (8, 8, 256);
    let (a, b) = gaussian_mats(dims.0, dims.1, dims.2, 0x5EED);
    // Same seed, two independently built sessions: identical bits.
    let first = sr_gemm_bits(7, 4, None, dims, &a, &b);
    let again = sr_gemm_bits(7, 4, None, dims, &a, &b);
    assert_eq!(first, again, "same-seed SR runs disagree");
    // A different seed must actually change the draws...
    let other = sr_gemm_bits(8, 4, None, dims, &a, &b);
    assert_ne!(first, other, "SR ignored the session seed");
    // ...and SR must differ from RNE on an inexact big-K problem.
    let session = Session::builder().seed(7).threads(4).build();
    let rne = session
        .gemm()
        .src(FP8)
        .acc(FP16)
        .dims(dims.0, dims.1, dims.2)
        .expect("plan")
        .run_f64(&a, &b)
        .expect("run");
    assert_ne!(first, bits(&rne.c_f64()), "SR session rounded exactly like RNE");
}

// --------------------------------------------- chunked error tightening

/// f64 reference GEMM over already-quantized operands.
fn gemm_f64(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn max_abs_err(c: &[f64], reference: &[f64]) -> f64 {
    c.iter().zip(reference).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn chunked_accumulation_tightens_big_k_error() {
    let (m, n, k) = (4, 4, 4096);
    let session = Session::builder().seed(11).build();
    let (a, b) = gaussian_mats(m, n, k, 0xB16C);
    // Quantize once through the same RNE grid the plans use, so the
    // reference isolates *accumulation* error from quantization error.
    let aq = session.tensor(&a, m, k, FP8).expect("a quant").to_f64();
    let bq = session.tensor(&b, k, n, FP8).expect("b quant").to_f64();
    let reference = gemm_f64(&aq, &bq, m, n, k);
    let naive = session
        .gemm()
        .src(FP8)
        .acc(FP16)
        .dims(m, n, k)
        .expect("naive plan")
        .run_f64(&a, &b)
        .expect("naive run");
    let chunked = session
        .gemm()
        .src(FP8)
        .acc(FP16)
        .chunk_k(256)
        .dims(m, n, k)
        .expect("chunked plan")
        .run_f64(&a, &b)
        .expect("chunked run");
    let err_naive = max_abs_err(&naive.c_f64(), &reference);
    let err_chunked = max_abs_err(&chunked.c_f64(), &reference);
    assert!(err_naive > 0.0, "K=4096 FP16 accumulation came out exact — probe is degenerate");
    assert!(
        err_chunked <= err_naive,
        "chunk tree worsened the K=4096 error: chunked {err_chunked:e} vs naive {err_naive:e}"
    );
}

#[test]
fn full_k_chunk_degenerates_to_the_naive_fold_bit_for_bit() {
    let (m, n, k) = (8, 8, 1024);
    let (a, b) = gaussian_mats(m, n, k, 0xF01D);
    // RNE and SR both: a single chunk spanning all of K reuses the
    // naive epilogue keys, so the results must match to the bit.
    for sr in [false, true] {
        let builder = Session::builder().seed(23);
        let session = if sr { builder.stochastic_rounding().build() } else { builder.build() };
        let run_with = |chunk: Option<usize>| {
            let mut plan = session.gemm().src(FP8).acc(FP16);
            if let Some(c) = chunk {
                plan = plan.chunk_k(c);
            }
            let run = plan.dims(m, n, k).expect("plan").run_f64(&a, &b).expect("run");
            bits(&run.c_f64())
        };
        assert_eq!(
            run_with(Some(k)),
            run_with(None),
            "sr={sr}: chunk_k(K) is not bit-identical to the naive plan"
        );
    }
}
