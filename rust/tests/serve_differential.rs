//! Serving scheduler differential gates
//! (`cargo test --test serve_differential`).
//!
//! The tentpole invariant of the continuous-batching rebuild: the
//! scheduler decides *when* a request runs, never *what* it computes.
//! Because every GEMM output row depends only on its own input row,
//! each response's logits must be bit-identical to its batch-of-1 run
//! — at any shard count, any thread budget, and under any join
//! schedule (continuous waves, legacy whole-batch, batch-of-1).
//!
//! Three gates:
//!  1. **Bit identity**: per-id logits and predictions equal across
//!     {Continuous, WholeBatch} x shards {1, 4} x thread budgets
//!     {1, 4, 7}, all against a WholeBatch `max_batch(1)` reference.
//!  2. **Completion-tick monotonicity**: replay emits responses in
//!     nondecreasing completion order within every arm.
//!  3. **Stats byte-stability**: `ServeStats::summary_json` is the
//!     identical byte string across shard counts, thread budgets and
//!     repeats within a scheduling mode (virtual time only — nothing
//!     wall-clock leaks in).

use minifloat_nn::prelude::*;
use minifloat_nn::serve::{sim, BatchMode, InferenceModel};
use minifloat_nn::util::parallel::with_worker_count;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Train-and-freeze one tenant model.
fn frozen(session: &Session, policy: PrecisionPolicy) -> InferenceModel {
    let mut tr = session.native_trainer(policy).expect("trainer");
    tr.train(4, 0).expect("train");
    InferenceModel::freeze(session, tr.model(), tr.policy()).expect("freeze")
}

/// One replay arm: `(per-id (logits, pred, completion), emission-order
/// completion ticks, stats JSON)`.
type Arm = (Vec<(u64, Vec<u64>, usize, u64)>, Vec<u64>, String);

fn run_arm(
    session: &Session,
    models: &[InferenceModel],
    trace: &sim::Trace,
    mode: BatchMode,
    max_batch: usize,
    shards: usize,
) -> Arm {
    let mut builder = session.server();
    for (i, m) in models.iter().enumerate() {
        builder = builder.tenant(&format!("t{i}"), m.clone());
    }
    let plan = builder
        .max_batch(max_batch)
        .max_wait_ticks(2)
        .shards(shards)
        .batching(mode)
        .build()
        .expect("plan");
    let mut server = plan.server();
    let responses = sim::replay(&mut server, trace).expect("replay");
    let emission: Vec<u64> = responses.iter().map(|r| r.completion_tick).collect();
    let mut keyed: Vec<(u64, Vec<u64>, usize, u64)> = responses
        .iter()
        .map(|r| (r.id, bits(&r.logits), r.pred, r.completion_tick))
        .collect();
    keyed.sort_by_key(|(id, ..)| *id);
    (keyed, emission, server.stats().summary_json())
}

#[test]
fn scheduling_never_changes_a_bit() {
    let session = Session::builder().seed(41).build();
    let models = [frozen(&session, PrecisionPolicy::hfp8()), frozen(&session, PrecisionPolicy::fp8())];
    // Two tenants, bursty-ish open loop with deadlines: exercises the
    // SLO-weighted wave composition and the legacy deadline trigger.
    let trace = sim::Trace::open_loop(4242, &[8, 8], 120, 0.3, Some(48)).expect("trace");

    // Reference: batch-of-1, run-to-completion, single shard.
    let (reference, _, _) = run_arm(&session, &models, &trace, BatchMode::WholeBatch, 1, 1);
    assert_eq!(reference.len(), 120);

    let mut stats_by_mode: std::collections::BTreeMap<&str, Vec<String>> = Default::default();
    let mut latency_sum = std::collections::BTreeMap::<&str, u64>::new();
    for (mode, mode_name) in
        [(BatchMode::Continuous, "continuous"), (BatchMode::WholeBatch, "whole")]
    {
        for shards in [1usize, 4] {
            for threads in [1usize, 4, 7] {
                let (keyed, emission, stats) = with_worker_count(threads, || {
                    run_arm(&session, &models, &trace, mode, 16, shards)
                });
                // Gate 1: per-id logits and predictions are bit-equal
                // to the batch-of-1 reference.
                assert_eq!(keyed.len(), reference.len());
                for ((id, logits, pred, _), (rid, rlogits, rpred, _)) in
                    keyed.iter().zip(&reference)
                {
                    assert_eq!(id, rid);
                    assert_eq!(
                        logits, rlogits,
                        "{mode_name}/shards={shards}/threads={threads}: request {id} \
                         diverged from its batch-of-1 logits"
                    );
                    assert_eq!(pred, rpred, "request {id}: prediction flipped");
                }
                // Gate 2: responses stream out in completion order.
                assert!(
                    emission.windows(2).all(|w| w[0] <= w[1]),
                    "{mode_name}/shards={shards}/threads={threads}: completion ticks \
                     not monotone: {emission:?}"
                );
                stats_by_mode.entry(mode_name).or_default().push(stats);
                *latency_sum.entry(mode_name).or_insert(0) +=
                    keyed.iter().map(|(_, _, _, c)| c).sum::<u64>();
            }
        }
        // Repeat one arm verbatim: byte-stable across runs too.
        let (_, _, again) = run_arm(&session, &models, &trace, mode, 16, 1);
        stats_by_mode.entry(mode_name).or_default().push(again);
    }
    // Gate 3: within a mode, every arm (shards x threads x repeat)
    // renders the identical stats JSON byte string.
    for (mode_name, renders) in &stats_by_mode {
        for r in &renders[1..] {
            assert_eq!(
                r, &renders[0],
                "{mode_name}: stats JSON not byte-stable across shards/threads/repeats"
            );
        }
    }
    // And the timing *should* differ between the modes — continuous
    // pipelines cohorts, whole-batch runs them to completion — which is
    // exactly why the bit-identity above is a nontrivial claim.
    let cont = latency_sum["continuous"];
    let whole = latency_sum["whole"];
    assert!(
        cont < whole,
        "continuous batching should finish the trace strictly earlier in aggregate \
         (continuous completion-tick sum {cont}, whole-batch {whole})"
    );
}

#[test]
fn bursty_traces_replay_bit_identically_across_schedulers() {
    // The MMPP arrival model feeds the same invariant: ON/OFF bursts
    // change *when* cohorts form, never what any row computes.
    let session = Session::builder().seed(43).build();
    let models = [frozen(&session, PrecisionPolicy::hfp8())];
    let trace = sim::Trace::bursty(99, &[8], 80, 0.4, 6.0, 24.0, Some(64)).expect("trace");
    let (reference, _, _) = run_arm(&session, &models, &trace, BatchMode::WholeBatch, 1, 1);
    for mode in [BatchMode::Continuous, BatchMode::WholeBatch] {
        for shards in [1usize, 4] {
            let (keyed, emission, _) = run_arm(&session, &models, &trace, mode, 8, shards);
            assert_eq!(keyed.len(), reference.len());
            for ((id, logits, pred, _), (rid, rlogits, rpred, _)) in keyed.iter().zip(&reference) {
                assert_eq!(id, rid);
                assert_eq!(logits, rlogits, "{mode:?}/shards={shards}: request {id} diverged");
                assert_eq!(pred, rpred);
            }
            assert!(emission.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}
