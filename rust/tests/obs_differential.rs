//! Observability differential gates (`cargo test --test obs_differential`).
//!
//! The obs layer's hard invariant: instrumentation on vs off is
//! **bit-identical** in every result word and every virtual cycle/tick
//! count. Each test here runs the same workload twice — obs fully
//! enabled, obs fully disabled — and compares bits, not tolerances.
//! The second family cross-checks the two bookkeeping views
//! (`ServeStats::summary_json` vs the obs snapshot) and pins snapshot
//! JSON byte-stability under different thread counts.
//!
//! Obs state is process-global, so every test serializes on
//! [`minifloat_nn::obs::test_guard`] and starts from a reset.

use minifloat_nn::batch::{with_lane_tier, LaneTier};
use minifloat_nn::obs;
use minifloat_nn::prelude::*;
use minifloat_nn::serve::sim;

fn gaussian_mats(m: usize, n: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = minifloat_nn::util::rng::Rng::new(seed);
    let a = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    (a, b)
}

/// Take the guard, reset to a known-clean disabled state, run `f`, and
/// leave obs disabled for whoever runs next.
fn with_clean_obs<R>(f: impl FnOnce() -> R) -> R {
    let _guard = obs::test_guard();
    obs::disable_all();
    obs::reset_all();
    let r = f();
    obs::disable_all();
    obs::reset_all();
    r
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ------------------------------------------------ bit/cycle identity

#[test]
fn batch_gemm_is_bit_identical_with_obs_on_both_lane_tiers() {
    with_clean_obs(|| {
        // Functional mode routes through the batch engine — the tier
        // dispatch, pack spans and gemm.tile spans all fire. 32x64x32
        // keeps the subprocess-free test fast.
        let (m, n, k) = (32, 64, 32);
        let (a, b) = gaussian_mats(m, n, k, 7);
        for tier in [LaneTier::Swar, LaneTier::Scalar] {
            let run_once = || {
                with_lane_tier(tier, || {
                    let session = Session::builder().mode(ExecMode::Functional).seed(7).build();
                    let run = session
                        .gemm()
                        .src(FP8)
                        .acc(FP16)
                        .dims(m, n, k)
                        .expect("plan")
                        .run_f64(&a, &b)
                        .expect("run");
                    (bits(&run.c_f64()), run.cycles)
                })
            };
            obs::disable_all();
            let (c_off, cy_off) = run_once();
            obs::enable_all();
            obs::reset_all();
            let (c_on, cy_on) = run_once();
            obs::disable_all();
            assert_eq!(c_on, c_off, "{tier:?}: obs flipped a result bit");
            assert_eq!(cy_on, cy_off, "{tier:?}: obs moved the modeled cycle count");
        }
    });
}

#[test]
fn sr_gemm_is_bit_identical_with_obs_on_and_counts_sr_runs() {
    with_clean_obs(|| {
        // A stochastically-rounded, chunked GEMM: the SR draw keys are
        // derived from (seed, element index) only, so flipping obs on
        // must not move a single bit — and the obs-on run must record
        // exactly one `numerics.sr.runs` plan execution.
        let (m, n, k) = (16, 32, 256);
        let (a, b) = gaussian_mats(m, n, k, 29);
        let run_once = || {
            let session = Session::builder()
                .mode(ExecMode::Functional)
                .seed(29)
                .stochastic_rounding()
                .build();
            let run = session
                .gemm()
                .src(FP8)
                .acc(FP16)
                .chunk_k(64)
                .dims(m, n, k)
                .expect("plan")
                .run_f64(&a, &b)
                .expect("run");
            bits(&run.c_f64())
        };
        obs::disable_all();
        let off = run_once();
        obs::enable_all();
        obs::reset_all();
        let on = run_once();
        let snap = obs::metrics::snapshot();
        obs::disable_all();
        assert_eq!(on, off, "obs flipped a stochastically-rounded result bit");
        assert_eq!(snap.counter("numerics.sr.runs"), 1, "SR plan run not counted");
    });
}

#[test]
fn native_training_is_bit_identical_with_obs_on() {
    with_clean_obs(|| {
        let run_once = || {
            let session = Session::builder().seed(13).build();
            let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
            tr.train(6, 0).expect("train");
            let hist: Vec<(usize, u64, u64, bool)> = tr
                .history
                .iter()
                .map(|r| (r.step, r.loss.to_bits(), r.scale.to_bits(), r.skipped))
                .collect();
            (hist, tr.gemm_calls(), tr.packed_runs(), tr.accuracy().expect("acc").to_bits())
        };
        obs::disable_all();
        let off = run_once();
        obs::enable_all();
        obs::reset_all();
        let on = run_once();
        obs::disable_all();
        assert_eq!(on, off, "obs perturbed the training trajectory");
    });
}

#[test]
fn serve_replay_is_bit_identical_with_obs_on_at_shard_counts_1_and_4() {
    with_clean_obs(|| {
        let session = Session::builder().seed(6).build();
        let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
        tr.train(5, 0).expect("train");
        let model =
            minifloat_nn::serve::InferenceModel::freeze(&session, tr.model(), tr.policy())
                .expect("freeze");
        let trace = sim::Trace::open_loop(11, &[8], 48, 0.5, Some(32)).expect("trace");
        for shards in [1usize, 4] {
            let plan = session
                .server()
                .tenant("t", model.clone())
                .max_batch(8)
                .max_wait_ticks(2)
                .shards(shards)
                .build()
                .expect("plan");
            let run_once = || {
                let mut server = plan.server();
                let responses = sim::replay(&mut server, &trace).expect("replay");
                let logits: Vec<Vec<u64>> = responses.iter().map(|r| bits(&r.logits)).collect();
                let ticks: Vec<u64> = responses.iter().map(|r| r.completion_tick).collect();
                (logits, ticks, server.stats().summary_json())
            };
            obs::disable_all();
            let off = run_once();
            obs::enable_all();
            obs::reset_all();
            let on = run_once();
            obs::disable_all();
            assert_eq!(on.0, off.0, "shards={shards}: obs flipped a logit bit");
            assert_eq!(on.1, off.1, "shards={shards}: obs moved a completion tick");
            assert_eq!(on.2, off.2, "shards={shards}: obs changed the stats JSON");
        }
    });
}

#[test]
fn soc_gemm_is_cycle_and_bit_identical_with_tracing_on() {
    with_clean_obs(|| {
        // One roofline-style row: the traced path runs
        // `schedule_with_events`, the untraced one `schedule` — same
        // resolver, so every cycle figure and every C bit must match.
        let (m, n, k) = (32, 32, 32);
        let (a, b) = gaussian_mats(m, n, k, 21);
        let soc = Soc::new(SocCfg { n_clusters: 2, ..SocCfg::default() }).expect("soc");
        let run_once = || {
            let r = soc
                .run_gemm(GemmKind::ExSdotp(minifloat_nn::isa::instr::OpWidth::BtoH), m, n, k, &a, &b)
                .expect("run");
            (bits(&r.c), r.total_cycles, r.compute_cycles, r.dma_stall_cycles, r.l2.read_bytes)
        };
        obs::disable_all();
        let off = run_once();
        obs::enable_all();
        obs::reset_all();
        let on = run_once();
        // The traced run must actually have produced SoC spans —
        // otherwise this test compares two untraced runs.
        let trace = obs::trace::chrome_json();
        obs::disable_all();
        assert_eq!(on, off, "tracing perturbed the SoC timeline or result");
        for span in ["dma.chunk", "compute.chunk", "writeback"] {
            assert!(trace.contains(span), "traced SoC run missing '{span}' spans");
        }
    });
}

// ------------------------------------------- cross-view consistency

#[test]
fn serve_stats_and_obs_snapshot_agree_on_shared_quantities() {
    with_clean_obs(|| {
        let session = Session::builder().seed(9).build();
        let mut tr = session.native_trainer(PrecisionPolicy::fp32()).expect("trainer");
        tr.train(4, 0).expect("train");
        let model =
            minifloat_nn::serve::InferenceModel::freeze(&session, tr.model(), tr.policy())
                .expect("freeze");
        let plan = session
            .server()
            .tenant("solo", model)
            .max_batch(8)
            .max_wait_ticks(2)
            .shards(2)
            .build()
            .expect("plan");
        // Enable only after training: the snapshot should describe the
        // serving run alone, like `repro serve --metrics` post-setup.
        obs::enable_all();
        obs::reset_all();
        let mut server = plan.server();
        let trace = sim::Trace::open_loop(17, &[8], 40, 0.5, Some(24)).expect("trace");
        sim::replay(&mut server, &trace).expect("replay");
        let snap = obs::metrics::snapshot();
        let stats = server.stats();
        obs::disable_all();
        // Dual-written at single choke points, so equality is by
        // construction — this is the regression net for the next person
        // who adds a second increment site.
        assert_eq!(snap.counter("serve.submitted"), stats.submitted);
        assert_eq!(snap.counter("serve.completed"), stats.completed);
        assert_eq!(snap.counter("serve.batches"), stats.batches);
        assert_eq!(snap.counter("serve.waves"), stats.waves);
        assert_eq!(snap.counter("serve.deadline_misses"), stats.deadline_misses);
        assert_eq!(snap.gauge("serve.ticks"), stats.ticks);
        assert_eq!(snap.gauge("serve.queue_depth_max"), stats.queue_depth_max as u64);
        assert_eq!(snap.counter("serve.tenant.solo.gemm_calls"), stats.gemm_calls());
        assert_eq!(snap.counter("serve.tenant.solo.packed_runs"), stats.packed_runs());
        let h = snap.hist("serve.batch_size").expect("batch-size hist");
        assert_eq!(h.count, stats.batches);
        let h = snap.hist("serve.wave_rows").expect("wave-rows hist");
        assert_eq!(h.count, stats.waves);
        assert_eq!(h.sum, stats.wave_rows);
        let h = snap.hist("serve.latency_ticks").expect("latency hist");
        assert_eq!(h.count, stats.completed);
    });
}

#[test]
fn snapshot_json_is_byte_stable_across_thread_counts() {
    with_clean_obs(|| {
        // The same logical workload sharded over 1, 4 and 7 threads
        // must snapshot to the identical byte string: merges are
        // commutative and the snapshot iterates sorted maps.
        let mut renders = Vec::new();
        for threads in [1usize, 4, 7] {
            obs::enable_all();
            obs::reset_all();
            std::thread::scope(|s| {
                for t in 0..threads {
                    s.spawn(move || {
                        // Round-robin split of a fixed work list: the
                        // per-thread share varies, the totals do not.
                        for i in (t..84).step_by(threads) {
                            minifloat_nn::obs_count!("difftest.events");
                            minifloat_nn::obs_count!("difftest.bytes", (i as u64) * 3);
                            minifloat_nn::obs_gauge_max!("difftest.peak", i as u64);
                            minifloat_nn::obs_hist!("difftest.lat", (i % 11) as u64);
                        }
                    });
                }
            });
            renders.push(obs::metrics::snapshot_json());
            obs::disable_all();
        }
        assert_eq!(renders[0], renders[1], "1-thread vs 4-thread snapshots differ");
        assert_eq!(renders[0], renders[2], "1-thread vs 7-thread snapshots differ");
        assert!(renders[0].contains("\"difftest.events\":84"), "{}", renders[0]);
    });
}

#[test]
fn trace_captures_the_span_taxonomy_end_to_end() {
    with_clean_obs(|| {
        obs::enable_all();
        obs::reset_all();
        // A blocked-shape GEMM (m ≥ 32, n ≥ 128, n·k/lanes over the
        // 2^13 threshold) so the `gemm.tile` loop fires, plus a short
        // training run for the nn spans (whose MfTensor packing fires
        // the `pack.rows`/`pack.cols` dispatchers).
        let (m, n, k) = (32, 128, 1024);
        let (a, b) = gaussian_mats(m, n, k, 3);
        let session = Session::builder().mode(ExecMode::Functional).seed(3).build();
        session
            .gemm()
            .src(FP8)
            .acc(FP16)
            .dims(m, n, k)
            .expect("plan")
            .run_f64(&a, &b)
            .expect("run");
        let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
        tr.train(2, 0).expect("train");
        let trace = obs::trace::chrome_json();
        obs::disable_all();
        for span in [
            "plan.compile",
            "plan.run",
            "pack.a",
            "pack.b",
            "pack.rows",
            "pack.cols",
            "gemm.tier",
            "gemm.tile",
            "train.step",
            "train.forward",
            "train.backward",
            "train.optim",
        ] {
            assert!(trace.contains(&format!("\"name\":\"{span}\"")), "missing span '{span}'");
        }
        assert!(trace.contains("\"traceEvents\""), "not a Chrome trace document");
    });
}
