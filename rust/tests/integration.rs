//! Cross-module integration tests (`cargo test --test integration`):
//! the reproduction pipeline end to end, including — when artifacts are
//! present — the PJRT runtime path.

use minifloat_nn::coordinator::{Precision, Trainer};
use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::kernels::{kernel_reference, GemmKernel, GemmKind};
use minifloat_nn::report;
use minifloat_nn::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let p = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&p).join("train_step_hfp8.hlo.txt").exists().then_some(p)
}

#[test]
fn table2_subset_reproduces_paper_shape() {
    // The three headline cells at 64×64, with the paper's ordering and
    // ±15% cycle agreement.
    let mut rng = Rng::new(42);
    let mut cycles = std::collections::HashMap::new();
    for (kind, paper) in [
        (GemmKind::FmaSimd(ScalarFmt::H), 12232u64),
        (GemmKind::ExSdotp(OpWidth::HtoS), 10968),
        (GemmKind::ExSdotp(OpWidth::BtoH), 7019),
    ] {
        let (m, n, k) = (64, 64, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
        let run = GemmKernel::new(kind, m, n, k).run(&a, &b);
        let dev = (run.cycles as f64 - paper as f64).abs() / paper as f64;
        assert!(dev < 0.15, "{}: {} vs paper {paper} ({:.0}% off)", kind.label(), run.cycles, dev * 100.0);
        cycles.insert(kind.label(), run.cycles);
    }
    assert!(cycles["FP16->FP32 ExSdotp"] < cycles["FP16 FMA"]);
    assert!(cycles["FP8->FP16 ExSdotp"] < cycles["FP16->FP32 ExSdotp"]);
}

#[test]
fn report_generators_produce_all_artifacts() {
    assert!(report::table1_text().contains("ExSdotp/ExVsum"));
    assert!(report::formats_text().contains("FP8alt"));
    assert!(report::fig2_text().contains("16 FLOP/cycle"));
    assert!(report::fig7a_text().contains("ratio"));
    assert!(report::fig7b_text().contains("SDOTP"));
    let t3 = report::table3_text(1);
    assert!(t3.contains("GFLOPS/W"));
    let t4 = report::table4_text(1);
    assert!(t4.contains("ExSdotp") && t4.contains("ExFMA"));
}

#[test]
fn gemm_sim_matches_reference_through_full_stack_128() {
    // One big problem through the whole simulator, bit-exact.
    let (m, n, k) = (32, 32, 64);
    let mut rng = Rng::new(5);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let kern = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k);
    let run = kern.run(&a, &b);
    let want = kernel_reference(&kern, &a, &b);
    assert_eq!(run.c, want);
}

#[test]
fn e2e_training_via_pjrt_converges() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut tr = Trainer::new(&dir, Precision::Hfp8, 42).expect("trainer");
    let first = tr.step().expect("step");
    for _ in 0..79 {
        tr.step().expect("step");
    }
    let last = tr.recent_loss(10);
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first * 0.75, "loss did not drop: {first} -> {last}");
    let acc = tr.accuracy().expect("accuracy");
    assert!(acc > 0.5, "accuracy {acc} too low after 80 steps");
}

#[test]
fn e2e_hfp8_matches_fp32_closely() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut losses = vec![];
    for p in [Precision::Hfp8, Precision::Fp32] {
        let mut tr = Trainer::new(&dir, p, 7).expect("trainer");
        for _ in 0..120 {
            tr.step().expect("step");
        }
        losses.push(tr.recent_loss(20));
    }
    let (hfp8, fp32) = (losses[0], losses[1]);
    assert!(
        (hfp8 - fp32).abs() < 0.4,
        "HFP8 ({hfp8}) should track the fp32 baseline ({fp32}) on this task"
    );
}
