//! Cross-module integration tests (`cargo test --test integration`):
//! the reproduction pipeline end to end through the typed API
//! ([`minifloat_nn::prelude`]), the `repro` binary's argument
//! validation, and — when artifacts are present — the PJRT runtime
//! path.

use minifloat_nn::coordinator::Precision;
use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::kernels::{kernel_reference, GemmKernel};
use minifloat_nn::prelude::*;
use minifloat_nn::report;

fn artifacts_dir() -> Option<String> {
    let p = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&p).join("train_step_hfp8.hlo.txt").exists().then_some(p)
}

fn gaussian_mats(m: usize, n: usize, k: usize, rng: &mut minifloat_nn::util::rng::Rng) -> (Vec<f64>, Vec<f64>) {
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    (a, b)
}

#[test]
fn table2_subset_reproduces_paper_shape() {
    // The three headline cells at 64×64, with the paper's ordering and
    // ±15% cycle agreement — run through Session/GemmPlan.
    let session = Session::builder().mode(ExecMode::CycleAccurate).seed(42).build();
    let mut rng = session.rng();
    let mut cycles = std::collections::HashMap::new();
    for (kind, paper) in [
        (GemmKind::FmaSimd(ScalarFmt::H), 12232u64),
        (GemmKind::ExSdotp(OpWidth::HtoS), 10968),
        (GemmKind::ExSdotp(OpWidth::BtoH), 7019),
    ] {
        let (m, n, k) = (64, 64, 64);
        let (a, b) = gaussian_mats(m, n, k, &mut rng);
        let plan = session.gemm().kind(kind).dims(m, n, k).expect("valid plan");
        let run = plan.run_f64(&a, &b).expect("valid run");
        let got = run.cycles.expect("cycle-accurate run");
        let dev = (got as f64 - paper as f64).abs() / paper as f64;
        assert!(dev < 0.15, "{}: {} vs paper {paper} ({:.0}% off)", kind.label(), got, dev * 100.0);
        cycles.insert(kind.label(), got);
    }
    assert!(cycles["FP16->FP32 ExSdotp"] < cycles["FP16 FMA"]);
    assert!(cycles["FP8->FP16 ExSdotp"] < cycles["FP16->FP32 ExSdotp"]);
}

#[test]
fn report_generators_produce_all_artifacts() {
    assert!(report::table1_text().contains("ExSdotp/ExVsum"));
    assert!(report::formats_text().contains("FP8alt"));
    assert!(report::fig2_text().contains("16 FLOP/cycle"));
    assert!(report::fig7a_text().contains("ratio"));
    assert!(report::fig7b_text().contains("SDOTP"));
    let t3 = report::table3_text(1);
    assert!(t3.contains("GFLOPS/W"));
    let t4 = report::table4_text(1);
    assert!(t4.contains("ExSdotp") && t4.contains("ExFMA"));
}

#[test]
fn gemm_sim_matches_reference_through_full_stack_128() {
    // One big problem through the whole simulator via the typed API,
    // bit-exact against the per-element reference replay.
    let (m, n, k) = (32, 32, 64);
    let session = Session::builder().mode(ExecMode::CycleAccurate).seed(5).build();
    let mut rng = session.rng();
    let (a, b) = gaussian_mats(m, n, k, &mut rng);
    let plan = session.gemm().src(FP8).acc(FP16).dims(m, n, k).expect("valid plan");
    let run = plan.run_f64(&a, &b).expect("valid run");
    let kern = GemmKernel::new(GemmKind::ExSdotp(OpWidth::BtoH), m, n, k);
    let want = kernel_reference(&kern, &a, &b);
    assert_eq!(run.c_f64(), want);
}

#[test]
fn new_api_pins_bit_identity_with_pre_redesign_path() {
    // Acceptance gate (redundant with the in-crate api::tests, but
    // exercised here as an external consumer would): FP8→FP16 and
    // FP16→FP32, both ExecModes, new plan API vs the old free-function
    // path, bit-identical C.
    let (m, n, k) = (16, 16, 16);
    let mut rng = minifloat_nn::util::rng::Rng::new(99);
    let (a, b) = gaussian_mats(m, n, k, &mut rng);
    for (src, acc, kind) in [
        (FP8, FP16, GemmKind::ExSdotp(OpWidth::BtoH)),
        (FP16, FP32, GemmKind::ExSdotp(OpWidth::HtoS)),
    ] {
        for mode in [ExecMode::Functional, ExecMode::CycleAccurate] {
            let session = Session::builder().mode(mode).build();
            let new = session
                .gemm()
                .src(src)
                .acc(acc)
                .dims(m, n, k)
                .expect("valid plan")
                .run_f64(&a, &b)
                .expect("valid run");
            let old = GemmKernel::new(kind, m, n, k).run_mode(&a, &b, mode);
            let new_bits: Vec<u64> = new.c_f64().iter().map(|x| x.to_bits()).collect();
            let old_bits: Vec<u64> = old.c.iter().map(|x| x.to_bits()).collect();
            assert_eq!(new_bits, old_bits, "{}→{} {mode:?}", src.name(), acc.name());
        }
    }
}

// ------------------------------------------------------ CLI validation

fn repro(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("spawn repro binary")
}

/// Bad arguments must produce a clean typed error on stderr and exit
/// code 1 — not a panic (which would exit 101).
fn assert_clean_cli_error(args: &[&str], needle: &str) {
    let out = repro(args);
    assert!(!out.status.success(), "{args:?} unexpectedly succeeded");
    assert_eq!(out.status.code(), Some(1), "{args:?} should exit 1 (a panic exits 101)");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains(needle), "{args:?} stderr missing '{needle}':\n{stderr}");
    assert!(!stderr.contains("panicked"), "{args:?} panicked:\n{stderr}");
}

#[test]
fn cli_rejects_malformed_size() {
    assert_clean_cli_error(&["gemm", "--size", "banana"], "--size must be MxN");
    assert_clean_cli_error(&["gemm", "--size", "0x64"], "--size must be MxN");
    // Well-formed but kernel-infeasible sizes get the divisibility error.
    assert_clean_cli_error(&["gemm", "--size", "10x10"], "must be a positive multiple");
}

#[test]
fn cli_rejects_unknown_kernel() {
    assert_clean_cli_error(&["gemm", "--kernel", "fp12"], "--kernel must be fp64|fp32|fp16|fp16to32|fp8");
}

#[test]
fn cli_rejects_unknown_mode() {
    assert_clean_cli_error(&["gemm", "--mode", "warp"], "--mode must be functional|cycle");
}

#[test]
fn cli_rejects_oversized_cycle_accurate_problem() {
    assert_clean_cli_error(&["gemm", "--size", "256x256", "--kernel", "fp64", "--mode", "cycle"], "128 kB");
    // The hint must name the CLI flag, not just the API enum.
    assert_clean_cli_error(&["gemm", "--size", "256x256", "--kernel", "fp64", "--mode", "cycle"], "--mode functional");
}

#[test]
fn cli_gemm_smoke_runs_through_the_api() {
    let out = repro(&["gemm", "--size", "16x16", "--kernel", "fp8", "--mode", "functional"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FP8->FP16 ExSdotp"), "{stdout}");
    assert!(stdout.contains("issue-slot model"), "{stdout}");
}

#[test]
fn cli_roofline_rejects_bad_cluster_lists() {
    assert_clean_cli_error(
        &["roofline", "--clusters", "two"],
        "--clusters must be a comma-separated list",
    );
    assert_clean_cli_error(&["roofline", "--clusters", "0"], "must be 1..=8");
    assert_clean_cli_error(&["roofline", "--clusters", "1,16"], "must be 1..=8");
}

#[test]
fn cli_roofline_rejects_bad_numeric_and_kernel_flags() {
    assert_clean_cli_error(&["roofline", "--k", "banana"], "--k expects a numeric value");
    assert_clean_cli_error(
        &["roofline", "--pairs", "fp12"],
        "--kernel must be fp64|fp32|fp16|fp16to32|fp8",
    );
    assert_clean_cli_error(&["roofline", "--mode", "warp"], "--mode must be functional|cycle");
    // Shape errors surface the kernel's own typed divisibility message.
    assert_clean_cli_error(&["roofline", "--size", "10x10"], "must be a positive multiple");
}

#[test]
fn cli_roofline_check_anchor_conflicts_with_functional_mode() {
    assert_clean_cli_error(
        &["roofline", "--clusters", "1", "--mode", "functional", "--check-anchor"],
        "--check-anchor",
    );
}

#[test]
fn cli_roofline_json_is_one_parseable_line() {
    // Functional mode keeps this subprocess test fast; the JSON must be
    // a single stdout line with energy columns explicitly null.
    let out = repro(&[
        "roofline",
        "--clusters",
        "1,2",
        "--size",
        "16x16",
        "--k",
        "16",
        "--pairs",
        "fp8",
        "--mode",
        "functional",
        "--json",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.trim().lines().count(), 1, "--json must print one line:\n{stdout}");
    assert!(stdout.starts_with("{\"roofline\":["), "{stdout}");
    assert!(stdout.contains("\"clusters\":1") && stdout.contains("\"clusters\":2"), "{stdout}");
    assert!(stdout.contains("\"cluster_gflops_per_w\":null"), "{stdout}");
}

// ------------------------------------------------- observability CLI

#[test]
fn cli_rejects_bad_obs_flags() {
    // A bare --trace parses as a flag, not an option — typed error.
    assert_clean_cli_error(&["gemm", "--size", "16x16", "--trace"], "--trace needs a file path");
    // An uncreatable path fails up front, before any simulated work.
    assert_clean_cli_error(
        &["gemm", "--size", "16x16", "--trace", "/nonexistent-dir/t.json"],
        "--trace: cannot create",
    );
    // --metrics is a flag; a trailing value is a typed error.
    assert_clean_cli_error(&["serve", "--metrics", "yes"], "--metrics takes no value");
    assert_clean_cli_error(&["train", "--metrics", "yes"], "--metrics takes no value");
}

#[test]
fn cli_gemm_trace_emits_chrome_trace_with_span_taxonomy() {
    // 256x512 (K = 256) FP8 crosses the blocking threshold, so the
    // trace must show the whole kernel taxonomy: plan compile/run,
    // operand packing, the tier dispatch and the tile loop.
    let path = std::env::temp_dir().join(format!("mfnn_trace_{}.json", std::process::id()));
    let path = path.to_str().expect("utf-8 temp path");
    let out = repro(&[
        "gemm", "--size", "256x512", "--kernel", "fp8", "--mode", "functional", "--trace", path,
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("trace written"),
        "stderr must note the trace file"
    );
    let trace = std::fs::read_to_string(path).expect("read trace file");
    std::fs::remove_file(path).ok();
    assert!(trace.starts_with('{') && trace.trim_end().ends_with('}'), "not a JSON object");
    assert!(trace.contains("\"traceEvents\""), "not a Chrome trace document");
    for span in ["plan.compile", "plan.run", "pack.a", "pack.b", "gemm.tier", "gemm.tile"] {
        assert!(trace.contains(&format!("\"name\":\"{span}\"")), "trace missing '{span}'");
    }
}

#[test]
fn cli_gemm_metrics_final_line_is_the_snapshot_json() {
    let out = repro(&["gemm", "--size", "16x16", "--kernel", "fp8", "--metrics"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("== observability roll-up =="), "{stdout}");
    let last = stdout.trim_end().lines().last().expect("nonempty stdout");
    assert!(
        last.starts_with("{\"counters\":{") && last.ends_with('}'),
        "final line must be the snapshot JSON, got: {last}"
    );
    assert!(last.contains("\"batch.tier."), "snapshot missing tier counters: {last}");
    assert!(last.contains("\"api.plan.runs\":1"), "{last}");
}

#[test]
fn cli_serve_json_with_metrics_is_one_parseable_line() {
    let out = repro(&[
        "serve", "--tenants", "hfp8", "--train-steps", "4", "--requests", "12", "--max-batch",
        "4", "--seed", "3", "--json", "--metrics",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.trim().lines().count(), 1, "--json must stay one line:\n{stdout}");
    assert!(stdout.starts_with("{\"serve\":{"), "{stdout}");
    assert!(stdout.contains(",\"obs\":{\"counters\":{"), "{stdout}");
    // The two views must agree on the shared quantity.
    assert!(stdout.contains("\"completed\":12"), "{stdout}");
    assert!(stdout.contains("\"serve.completed\":12"), "{stdout}");
}

#[test]
fn cli_roofline_json_with_metrics_merges_obs_into_the_object() {
    let out = repro(&[
        "roofline", "--clusters", "1", "--size", "16x16", "--k", "16", "--pairs", "fp8",
        "--mode", "functional", "--json", "--metrics",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.trim().lines().count(), 1, "--json must stay one line:\n{stdout}");
    assert!(stdout.starts_with("{\"roofline\":["), "{stdout}");
    assert!(stdout.contains(",\"obs\":{\"counters\":{"), "{stdout}");
    assert!(stdout.contains("\"soc.cycles.total\":"), "{stdout}");
}

// --------------------------------------------------------- PJRT (e2e)

#[test]
fn e2e_training_via_pjrt_converges() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let session = Session::builder().seed(42).build();
    let mut tr = session.trainer(&dir, Precision::Hfp8).expect("trainer");
    let first = tr.step().expect("step");
    for _ in 0..79 {
        tr.step().expect("step");
    }
    let last = tr.recent_loss(10);
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first * 0.75, "loss did not drop: {first} -> {last}");
    let acc = tr.accuracy().expect("accuracy");
    assert!(acc > 0.5, "accuracy {acc} too low after 80 steps");
}

#[test]
fn e2e_hfp8_matches_fp32_closely() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let session = Session::builder().seed(7).build();
    let mut losses = vec![];
    for p in [Precision::Hfp8, Precision::Fp32] {
        let mut tr = session.trainer(&dir, p).expect("trainer");
        for _ in 0..120 {
            tr.step().expect("step");
        }
        losses.push(tr.recent_loss(20));
    }
    let (hfp8, fp32) = (losses[0], losses[1]);
    assert!(
        (hfp8 - fp32).abs() < 0.4,
        "HFP8 ({hfp8}) should track the fp32 baseline ({fp32}) on this task"
    );
}

// ------------------------------------------------- native training CLI

#[test]
fn cli_train_pjrt_fails_cleanly_and_names_the_native_engine() {
    // Offline there is no PJRT backend: `train --engine pjrt` must be a
    // typed error (exit 1, no panic) that tells the user the native
    // engine works. Skip when artifacts + a PJRT build are present.
    if artifacts_dir().is_some() {
        eprintln!("skipping: artifacts present, PJRT may actually run");
        return;
    }
    assert_clean_cli_error(&["train", "--engine", "pjrt", "--steps", "1"], "--engine native");
    assert_clean_cli_error(&["train", "--engine", "pjrt", "--steps", "1"], "PJRT");
}

#[test]
fn cli_train_rejects_bad_arguments() {
    assert_clean_cli_error(&["train", "--engine", "warp"], "--engine must be native|pjrt");
    assert_clean_cli_error(&["train", "--precision", "fp12"], "--precision must be fp32|fp16|fp16alt|fp8|hfp8");
    assert_clean_cli_error(&["train", "--dataset", "mnist"], "--dataset must be spiral|rings");
    assert_clean_cli_error(&["train", "--optim", "lamb"], "--optim must be adam|sgd");
    assert_clean_cli_error(&["train", "--act", "swish"], "--act must be relu|gelu");
    // Lane-infeasible hidden width is a typed plan-build error.
    assert_clean_cli_error(&["train", "--hidden", "20"], "multiple of 8");
}

#[test]
fn cli_train_native_smoke() {
    let out = repro(&["train", "--steps", "5", "--quiet", "--precision", "hfp8"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("native training: policy hfp8"), "{stdout}");
    assert!(stdout.contains("packed fast path"), "{stdout}");
}

// ---------------------------------------------------- serving CLI / e2e

#[test]
fn cli_serve_rejects_bad_arguments() {
    assert_clean_cli_error(
        &["serve", "--tenants", "fp12", "--train-steps", "1"],
        "--tenants must list precision policies",
    );
    assert_clean_cli_error(
        &["serve", "--tenants", "hfp8,hfp8", "--train-steps", "1"],
        "lists 'hfp8' twice",
    );
    assert_clean_cli_error(&["serve", "--max-batch", "0", "--train-steps", "1"], "--max-batch");
    // A numeric typo must be an error, not a silent default config.
    assert_clean_cli_error(&["serve", "--max-batch", "6k"], "--max-batch expects");
    assert_clean_cli_error(&["serve", "--shards", "0", "--train-steps", "1"], "shard count");
    assert_clean_cli_error(
        &["serve", "--load", "warp", "--tenants", "hfp8", "--train-steps", "1"],
        "--load must be open|bursty|closed",
    );
    // The admission/scheduling knobs reject bad input before training.
    assert_clean_cli_error(
        &["serve", "--batching", "sometimes", "--train-steps", "1"],
        "unknown batching mode 'sometimes'",
    );
    assert_clean_cli_error(
        &["serve", "--queue-cap", "9999999999", "--train-steps", "1"],
        "queue_cap",
    );
    assert_clean_cli_error(
        &["serve", "--rate-limit", "-3", "--train-steps", "1"],
        "--rate-limit must be a positive",
    );
    assert_clean_cli_error(&["serve", "--checkpoint", "/nonexistent/model.bin"], "checkpoint");
    // --checkpoint and --tenants are mutually exclusive, loudly.
    assert_clean_cli_error(
        &["serve", "--checkpoint", "m.bin", "--tenants", "hfp8"],
        "conflicts with",
    );
}

#[test]
fn cli_serve_smoke_open_loop() {
    let out = repro(&[
        "serve",
        "--tenants",
        "hfp8",
        "--train-steps",
        "8",
        "--requests",
        "24",
        "--max-batch",
        "8",
        "--seed",
        "3",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("served 24 responses"), "{stdout}");
    assert!(stdout.contains("continuous batching"), "{stdout}");
    assert!(stdout.contains("p50"), "{stdout}");
    assert!(stdout.contains("tenant hfp8"), "{stdout}");
    assert!(stdout.contains("100% packed fast path"), "{stdout}");
}

#[test]
fn cli_serve_bursty_load_with_admission_control() {
    // The backpressure path end to end: an MMPP bursty trace against a
    // token bucket and a bounded queue, on the legacy scheduler for
    // variety. Sheds show up in the stats JSON; everything stays one
    // parseable line.
    let out = repro(&[
        "serve", "--tenants", "hfp8", "--train-steps", "4", "--requests", "32", "--max-batch",
        "8", "--load", "bursty", "--rate", "16", "--on-ticks", "4", "--off-ticks", "16",
        "--rate-limit", "2", "--burst", "4", "--queue-cap", "16", "--batching", "whole",
        "--seed", "5", "--json",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.trim().lines().count(), 1, "--json must stay one line:\n{stdout}");
    assert!(stdout.contains("\"shed_rate_limited\":"), "{stdout}");
    assert!(stdout.contains("\"goodput_per_tick\":"), "{stdout}");
    assert!(stdout.contains("\"waves\":"), "{stdout}");
}

#[test]
fn cli_train_save_then_serve_checkpoint() {
    // The README's end-to-end story: train -> checkpoint -> serve.
    let dir = std::env::temp_dir();
    let path = dir.join(format!("mfnn_cli_ckpt_{}.bin", std::process::id()));
    let path = path.to_str().expect("utf-8 temp path");
    let out = repro(&["train", "--steps", "8", "--quiet", "--precision", "fp8", "--save", path]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("checkpoint saved"),
        "{}",
        String::from_utf8_lossy(&out.stdout)
    );
    let out = repro(&["serve", "--checkpoint", path, "--requests", "16", "--json"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"completed\":16"), "{stdout}");
    std::fs::remove_file(path).ok();
}

#[test]
fn serving_trace_is_deterministic_end_to_end() {
    // Same seed + trace through the *library* path twice: identical
    // response bits and identical stats JSON (the CLI --json payload).
    use minifloat_nn::serve::{sim, InferenceModel};
    let session = Session::builder().seed(6).build();
    let mut tr = session.native_trainer(PrecisionPolicy::hfp8()).expect("trainer");
    tr.train(6, 0).expect("train");
    let model = InferenceModel::freeze(&session, tr.model(), tr.policy()).expect("freeze");
    let plan = session
        .server()
        .tenant("t", model)
        .max_batch(8)
        .max_wait_ticks(2)
        .shards(2)
        .build()
        .expect("plan");
    let trace = sim::Trace::open_loop(11, &[8], 60, 0.5, Some(32)).expect("trace");
    let run = || {
        let mut server = plan.server();
        let responses = sim::replay(&mut server, &trace).expect("replay");
        (responses, server.stats().summary_json())
    };
    let (ra, ja) = run();
    let (rb, jb) = run();
    assert_eq!(ja, jb, "stats JSON must be byte-identical");
    assert_eq!(ra.len(), 60);
    for (a, b) in ra.iter().zip(&rb) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.completion_tick, b.completion_tick);
        let (la, lb): (Vec<u64>, Vec<u64>) = (
            a.logits.iter().map(|v| v.to_bits()).collect(),
            b.logits.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(la, lb, "request {}", a.id);
    }
}

// ------------------------------------------- native training (blocking)

#[test]
fn native_training_convergence_smoke() {
    // The subsystem's acceptance gate, run natively (no artifacts, no
    // PJRT): HFP8 — FP8alt forward / FP8 backward operands, FP16
    // ExSdotp accumulation, FP32 master weights, dynamic loss scaling —
    // must solve the spiral task and land within 2 points of the native
    // FP32 baseline, with every matmul a packed GemmPlan run.
    let session = Session::builder().seed(42).build();
    let mut accs = Vec::new();
    for policy in [PrecisionPolicy::hfp8(), PrecisionPolicy::fp32()] {
        let mut tr = session.native_trainer(policy).expect("trainer");
        tr.train(500, 0).expect("train");
        let acc = tr.accuracy().expect("accuracy");
        if policy.fwd != policy.acc {
            assert_eq!(
                tr.packed_runs(),
                tr.gemm_calls(),
                "{}: every GEMM must run the packed plan route",
                policy.name
            );
        }
        accs.push((policy.name, acc));
    }
    let (hfp8, fp32) = (accs[0].1, accs[1].1);
    assert!(hfp8 >= 0.90, "HFP8 accuracy {hfp8} below the 90% gate");
    assert!(fp32 >= 0.90, "FP32 baseline accuracy {fp32} below the 90% gate");
    assert!(
        fp32 - hfp8 <= 0.02,
        "HFP8 ({hfp8}) must land within 2 points of the FP32 baseline ({fp32})"
    );
}
