//! Run a GEMM kernel on the simulated MiniFloat-NN cluster and inspect
//! the machine: cycles, utilization, stall breakdown, generated
//! assembly.
//!
//! ```sh
//! cargo run --release --example gemm_cluster -- [--size 64x64] [--kernel fp8]
//! ```
//! kernels: fp64 | fp32 | fp16 | fp16to32 | fp8

use minifloat_nn::isa::asm::disassemble_program;
use minifloat_nn::isa::instr::{OpWidth, ScalarFmt};
use minifloat_nn::kernels::{reference_gemm_f64, GemmKernel, GemmKind};
use minifloat_nn::util::cli::Args;
use minifloat_nn::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let size = args.get_str("size", "64x64");
    let (m, n) = size.split_once('x').map(|(a, b)| (a.parse().unwrap(), b.parse().unwrap())).unwrap_or((64, 64));
    let k = m;
    let kind = match args.get_str("kernel", "fp8").as_str() {
        "fp64" => GemmKind::FmaF64,
        "fp32" => GemmKind::FmaSimd(ScalarFmt::S),
        "fp16" => GemmKind::FmaSimd(ScalarFmt::H),
        "fp16to32" => GemmKind::ExSdotp(OpWidth::HtoS),
        _ => GemmKind::ExSdotp(OpWidth::BtoH),
    };

    let kern = GemmKernel::new(kind, m, n, k);
    println!("kernel: {}   problem: {m}x{n} (K={k})", kind.label());
    println!("TCDM footprint: {} bytes (logical)", kern.footprint());

    // Show what one core actually executes.
    println!("\ngenerated program (core 0):\n{}", disassemble_program(&kern.program(0)));

    let mut rng = Rng::new(7);
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let run = kern.run(&a, &b);

    let s = run.stats;
    println!("cycles            : {}", run.cycles);
    println!("FLOP              : {}", run.flops);
    println!("FLOP/cycle        : {:.2}", run.flop_per_cycle());
    println!("FP ops issued     : {}", s.fp_issued);
    println!("SSR elements      : {}", s.ssr_elems);
    println!("stalls (RAW)      : {}", s.stall_raw);
    println!("stalls (bank)     : {}", s.stall_bank);
    println!("int instructions  : {}", s.int_retired);

    // Sanity: compare a few entries against the f64 oracle.
    let gold = reference_gemm_f64(&a, &b, m, n, k);
    let mut worst = 0f64;
    for (g, r) in gold.iter().zip(&run.c) {
        worst = worst.max((g - r).abs() / g.abs().max(1.0));
    }
    println!("worst rel. error vs f64: {worst:.3e} (expected: set by the source format)");
}
