//! Run a GEMM kernel on the simulated MiniFloat-NN cluster and inspect
//! the machine: cycles, utilization, stall breakdown, generated
//! assembly — driven through the typed `Session`/`GemmPlan` API.
//!
//! ```sh
//! cargo run --release --example gemm_cluster -- [--size 64x64] [--kernel fp8]
//! ```
//! kernels: fp64 | fp32 | fp16 | fp16to32 | fp8

use minifloat_nn::api;
use minifloat_nn::isa::asm::disassemble_program;
use minifloat_nn::kernels::reference_gemm_f64;
use minifloat_nn::prelude::*;
use minifloat_nn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let (m, n) = api::parse_size(&args.get_str("size", "64x64"))?;
    let k = m;
    let kind = api::parse_kernel(&args.get_str("kernel", "fp8"))?;

    let session = Session::builder().mode(ExecMode::CycleAccurate).seed(7).build();
    let plan = session.gemm().kind(kind).dims(m, n, k)?;
    println!("kernel: {}   problem: {m}x{n} (K={k})", kind.label());
    println!("TCDM footprint: {} bytes (logical)", plan.kernel().footprint());

    // Show what one core actually executes.
    println!("\ngenerated program (core 0):\n{}", disassemble_program(&plan.kernel().program(0)));

    let mut rng = session.rng();
    let a: Vec<f64> = (0..m * k).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.gaussian() * 0.25).collect();
    let run = plan.run_f64(&a, &b)?;

    let s = run.stats.expect("cycle-accurate runs collect stats");
    println!("cycles            : {} ({})", run.cycles.unwrap_or(0), run.timing_label());
    println!("FLOP              : {}", run.flops);
    println!("FLOP/cycle        : {:.2}", run.flop_per_cycle().unwrap_or(0.0));
    println!("FP ops issued     : {}", s.fp_issued);
    println!("SSR elements      : {}", s.ssr_elems);
    println!("stalls (RAW)      : {}", s.stall_raw);
    println!("stalls (bank)     : {}", s.stall_bank);
    println!("int instructions  : {}", s.int_retired);

    // Sanity: compare a few entries against the f64 oracle.
    let gold = reference_gemm_f64(&a, &b, m, n, k);
    let c = run.c_f64();
    let mut worst = 0f64;
    for (g, r) in gold.iter().zip(&c) {
        worst = worst.max((g - r).abs() / g.abs().max(1.0));
    }
    println!("worst rel. error vs f64: {worst:.3e} (expected: set by the source format)");
    Ok(())
}
