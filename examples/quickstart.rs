//! Quickstart: the typed front door, then the low-level objects.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use minifloat_nn::exsdotp::{exsdotp_cascade, exsdotp_exact, ExSdotpUnit};
use minifloat_nn::prelude::*;
use minifloat_nn::softfloat::{from_f64, to_f64};

fn main() -> Result<()> {
    let rm = RoundingMode::Rne;

    // --- the typed API: Session → MfTensor → GemmPlan → RunReport ----
    // FP8 sources, FP16 expanding accumulation — the paper's headline
    // kernel — validated at plan-build time, run on the batch engine.
    let session = Session::builder().mode(ExecMode::Functional).seed(42).build();
    let mut rng = session.rng();
    let a: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
    let b: Vec<f64> = (0..16 * 16).map(|_| rng.gaussian() * 0.25).collect();
    // A packs row-major, B column-major — the layouts the kernel
    // streams, so run() feeds the packed words to the engine directly
    // (zero decode/re-pack).
    let ta = session.tensor(&a, 16, 16, FP8)?; // 8 lanes per 64-bit word
    let tb = session.tensor_with_layout(&b, 16, 16, FP8, Layout::ColMajor)?;
    let report = session.gemm().src(FP8).acc(FP16).dims(16, 16, 16)?.run(&ta, &tb)?;
    println!(
        "FP8->FP16 16x16 GEMM: {} FLOP, {:.1} FLOP/cycle (modeled), C[0][0] = {:.4}",
        report.flops,
        report.flop_per_cycle().unwrap_or(0.0),
        report.c.get(0, 0)
    );
    // Unsupported combinations are typed errors, not panics:
    let err = session.gemm().src(FP8).acc(FP32).dims(16, 16, 16).unwrap_err();
    println!("rejected at plan build: {err}\n");

    // --- minifloat encode/decode -------------------------------------
    let x = from_f64(1.1, FP8, rm);
    println!("1.1 quantized to FP8 (e5m2): bits {x:#04x} = {}", to_f64(x, FP8));

    // --- the paper's core operation ----------------------------------
    // ExSdotp: a*b + c*d + e with FP16 sources and FP32 accumulation,
    // fused (single rounding).
    let unit = ExSdotpUnit::fp16_to_fp32();
    let (a, b) = (from_f64(1.5, FP16, rm), from_f64(2.0, FP16, rm));
    let (c, d) = (from_f64(-0.75, FP16, rm), from_f64(4.0, FP16, rm));
    let e = from_f64(10.0, FP32, rm);
    let fused = unit.exsdotp(a, b, c, d, e, rm);
    println!("exsdotp(1.5*2.0 + -0.75*4.0 + 10.0) = {}", to_f64(fused, FP32));

    // --- why fusion matters -------------------------------------------
    // Build the paper's non-associativity example: a*1 + (-a)*1 + tiny.
    // The fused unit recovers `tiny`; the two-ExFMA cascade can lose it.
    let one = from_f64(1.0, FP16, rm);
    let big = from_f64(60000.0, FP16, rm);
    let nbig = big | FP16.sign_mask();
    let tiny = from_f64(2f64.powi(-20), FP32, rm);

    let fused = unit.exsdotp(big, one, nbig, one, tiny, rm);
    let casc = exsdotp_cascade(FP16, FP32, big, one, nbig, one, tiny, rm);
    let exact = exsdotp_exact(FP16, FP32, big, one, nbig, one, tiny, rm);
    println!("cancellation test: fused={} cascade={} exact={}", to_f64(fused, FP32), to_f64(casc, FP32), to_f64(exact, FP32));
    assert_eq!(fused, exact, "the fused datapath preserves the tiny addend");

    // --- accuracy over an accumulation (mini Table IV) -----------------
    let p = minifloat_nn::accuracy::accumulate(FP8, FP16, 1000, 42);
    println!(
        "accumulate 1000 FP8 dot products -> FP16: rel.err fused {:.2e}, cascade {:.2e}",
        p.err_exsdotp, p.err_exfma
    );

    println!("quickstart OK");
    Ok(())
}
