//! **End-to-end driver** (DESIGN.md E2E): train the HFP8 MLP through the
//! full three-layer stack — Rust coordinator → PJRT runtime → AOT HLO
//! artifacts containing the Pallas ExSdotp GEMM kernels — and compare
//! against the f32 baseline artifact.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example train_minifloat -- [--steps 300] [--seed 42]
//! ```

use minifloat_nn::api::Session;
use minifloat_nn::coordinator::Precision;
use minifloat_nn::util::cli::Args;
use minifloat_nn::util::error::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps: usize = args.get("steps", 300);
    let seed: u64 = args.get("seed", 42);
    let dir = args.get_str("artifacts", "artifacts");

    println!("=== E2E: HFP8 (FP8alt fwd / FP8 bwd, FP16 acc) vs FP32, {steps} steps ===\n");

    // One session owns the run policy (here: the seed); both precision
    // arms train from the same starting point.
    let session = Session::builder().seed(seed).build();
    let mut results = Vec::new();
    for precision in [Precision::Hfp8, Precision::Fp32] {
        println!("--- {precision:?} ---");
        let mut tr = session.trainer(&dir, precision)?;
        for i in 0..steps {
            let loss = tr.step()?;
            if i % (steps / 10).max(1) == 0 {
                println!("step {i:>4}  loss {loss:.4}");
            }
        }
        let final_loss = tr.recent_loss(20);
        let acc = tr.accuracy()?;
        println!("{precision:?}: mean final loss {final_loss:.4}, accuracy {:.1}%\n", acc * 100.0);
        results.push((precision, final_loss, acc));
    }

    println!("=== summary ===");
    for (p, loss, acc) in &results {
        println!("{:<12} loss {loss:.4}  accuracy {:.1}%", format!("{p:?}"), acc * 100.0);
    }
    let (_, hfp8_loss, _) = results[0];
    let (_, fp32_loss, _) = results[1];
    println!(
        "\nHFP8 final loss is within {:.3} of the f32 baseline — the paper's\n\
         low-precision-training premise (Sun et al. [7]) holds on this stack.",
        (hfp8_loss - fp32_loss).abs()
    );
    Ok(())
}
