//! **End-to-end training driver**: HFP8 mixed-precision vs the FP32
//! baseline on the native engine — every matmul a validated
//! `Session::gemm` plan on the ExSdotp batch engine, FP32 master
//! weights, dynamic loss scaling. Runs fully offline.
//!
//! ```sh
//! cargo run --release --example train_minifloat -- [--steps 500] [--seed 42]
//! ```
//!
//! `--engine pjrt` drives the original artifact-backed path instead
//! (three-layer stack → PJRT runtime → AOT HLO artifacts; requires a
//! PJRT-enabled build plus `make artifacts`).

use minifloat_nn::coordinator::Precision;
use minifloat_nn::prelude::*;
use minifloat_nn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps: usize = args.get("steps", 500);
    let seed: u64 = args.get("seed", 42);

    if args.get_str("engine", "native") == "pjrt" {
        return pjrt_engine(&args, steps, seed);
    }

    println!("=== native E2E: HFP8 (FP8alt fwd / FP8 bwd, FP16 acc) vs FP32, {steps} steps ===\n");

    // One session owns the run policy (seed, engine); both precision
    // arms train from the same starting point.
    let session = Session::builder().seed(seed).build();
    let mut results = Vec::new();
    for policy in [PrecisionPolicy::hfp8(), PrecisionPolicy::fp32()] {
        println!("--- {} ---", policy.name);
        let mut tr = session.native_trainer(policy)?;
        tr.train(steps, (steps / 10).max(1))?;
        let final_loss = tr.recent_loss(20);
        let acc = tr.accuracy()?;
        println!(
            "{}: mean final loss {final_loss:.4}, accuracy {:.1}%  ({} GemmPlan runs, {:.0}% packed)\n",
            policy.name,
            acc * 100.0,
            tr.gemm_calls(),
            100.0 * tr.packed_runs() as f64 / tr.gemm_calls().max(1) as f64
        );
        results.push((policy.name, final_loss, acc));
    }

    println!("=== summary ===");
    for (name, loss, acc) in &results {
        println!("{name:<12} loss {loss:.4}  accuracy {:.1}%", acc * 100.0);
    }
    let (_, _, hfp8_acc) = results[0];
    let (_, _, fp32_acc) = results[1];
    println!(
        "\nHFP8 accuracy is within {:.1} points of the FP32 baseline — the paper's\n\
         low-precision-training premise (Sun et al. [7], Wang et al.) holds on this stack.",
        (fp32_acc - hfp8_acc).abs() * 100.0
    );
    Ok(())
}

/// The original artifact-backed comparison (kept as the PJRT fallback).
fn pjrt_engine(args: &Args, steps: usize, seed: u64) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    println!("=== PJRT E2E: HFP8 vs FP32 via AOT artifacts, {steps} steps ===\n");
    let session = Session::builder().seed(seed).build();
    for precision in [Precision::Hfp8, Precision::Fp32] {
        println!("--- {precision:?} ---");
        let mut tr = session.trainer(&dir, precision)?;
        for i in 0..steps {
            let loss = tr.step()?;
            if i % (steps / 10).max(1) == 0 {
                println!("step {i:>4}  loss {loss:.4}");
            }
        }
        let acc = tr.accuracy()?;
        println!("{precision:?}: mean final loss {:.4}, accuracy {:.1}%\n", tr.recent_loss(20), acc * 100.0);
    }
    Ok(())
}
