//! The paper's parameterization claim (§III-A): "new formats can be
//! rapidly defined and explored." Define a *custom* minifloat — e5m1,
//! an extreme-range 8-bit format — and run the full evaluation loop
//! (unit instantiation, accuracy sweep, area estimate) without touching
//! any library code.
//!
//! ```sh
//! cargo run --release --example format_explorer
//! ```

use minifloat_nn::area::{exfma_unit_ge, exsdotp_unit_ge};
use minifloat_nn::exsdotp::{exsdotp_cascade, ExSdotpUnit};
use minifloat_nn::softfloat::{from_f64, to_f64};
use minifloat_nn::util::rng::Rng;
use minifloat_nn::{FpFormat, RoundingMode, FP16, FP8, FP8ALT};

fn main() {
    // One line defines a new format, like FPnew's parameter pack.
    let e5m1 = FpFormat::new(5, 1);
    let e3m4 = FpFormat::new(3, 4);
    println!("custom formats: {} (range 2^±~{}), {} (range 2^±~{})", e5m1.name(), e5m1.emax(), e3m4.name(), e3m4.emax());

    // Instantiate ExSdotp units for each 8-bit source → FP16.
    let rm = RoundingMode::Rne;
    println!("\naccuracy of a 1000-product Gaussian accumulation into FP16:");
    println!("{:<8} {:>14} {:>14} {:>12}", "src", "fused", "cascade", "unit GE");
    for src in [FP8, FP8ALT, e5m1, e3m4] {
        let unit = ExSdotpUnit::new(src, FP16);
        let mut rng = Rng::new(11);
        let mut acc = 0u64;
        let mut acc_c = 0u64;
        let mut gold = 0f64;
        for _ in 0..500 {
            let q = |r: &mut Rng| from_f64(r.gaussian(), src, rm);
            let (a, b, c, d) = (q(&mut rng), q(&mut rng), q(&mut rng), q(&mut rng));
            acc = unit.exsdotp(a, b, c, d, acc, rm);
            acc_c = exsdotp_cascade(src, FP16, a, b, c, d, acc_c, rm);
            gold += to_f64(a, src) * to_f64(b, src) + to_f64(c, src) * to_f64(d, src);
        }
        let rel = |x: u64| ((to_f64(x, FP16) - gold) / gold).abs();
        println!(
            "{:<8} {:>14.3e} {:>14.3e} {:>12.0}",
            src.name(),
            rel(acc),
            rel(acc_c),
            exsdotp_unit_ge(src, FP16)
        );
    }

    println!("\narea scaling: a fused unit vs two ExFMAs, per source format:");
    for src in [FP8, FP8ALT, e5m1, e3m4] {
        let f = exsdotp_unit_ge(src, FP16);
        let c = 2.0 * exfma_unit_ge(src, FP16);
        println!("{:<8} fused/cascade = {:.2}", src.name(), f / c);
    }

    println!("\nTrade-off visible above: more mantissa (e3m4) → better accuracy,");
    println!("more area; more exponent (e5m1) → range without accuracy. That is");
    println!("the exploration loop the paper's parameterization enables.");
}
