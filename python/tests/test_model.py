"""L2 model tests: shapes, gradient flow, HFP8 training actually learns,
and the AOT artifacts lower."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import artifacts, to_hlo_text


def spirals(n_per_class, key):
    """Three-arm spiral dataset (the classic toy classification task)."""
    ks = jax.random.split(key, 3)
    xs, ys = [], []
    for c in range(3):
        t = jnp.linspace(0.1, 1.0, n_per_class)
        theta = t * 4.5 + c * 2.1 + jax.random.normal(ks[c], (n_per_class,)) * 0.1
        r = t
        xy = jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=-1)
        xs.append(xy)
        ys.append(jnp.full((n_per_class,), c))
    return jnp.concatenate(xs), jnp.concatenate(ys)


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((model.BATCH, model.FEATURES), jnp.float32)
    logits = model.forward(params, x, quantized=True)
    assert logits.shape == (model.BATCH, model.CLASSES)
    assert jnp.isfinite(logits).all()


def test_gradients_flow_through_quantized_matmuls():
    params = model.init_params(jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (model.BATCH, model.FEATURES))
    y = jax.nn.one_hot(jnp.zeros(model.BATCH, jnp.int32), model.CLASSES)
    grads = jax.grad(model.loss_fn)(params, x, y, True)
    for name, g in grads.items():
        assert jnp.isfinite(g).all(), name
        assert float(jnp.abs(g).max()) > 0, f"{name} gradient is identically zero"


@pytest.mark.parametrize("quantized", [False, True], ids=["fp32", "hfp8"])
def test_training_reduces_loss(quantized):
    key = jax.random.PRNGKey(7)
    params = model.init_params(key)
    xy, labels = spirals(100, jax.random.PRNGKey(3))
    x_all = model.embed(xy)
    y_all = jax.nn.one_hot(labels, model.CLASSES)
    step = jax.jit(model.make_train_step(quantized=quantized, lr=0.1))

    rng = np.random.default_rng(0)
    losses = []
    p = [params[k] for k in ["w1", "b1", "w2", "b2", "w3", "b3"]]
    for i in range(40):
        idx = rng.choice(len(x_all), model.BATCH, replace=False)
        out = step(*p, x_all[idx], y_all[idx])
        p = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.8, f"loss did not drop: {losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses).all()


def test_hfp8_tracks_fp32_training():
    # The HFP8 claim (Sun et al.): low-precision training reaches a loss
    # close to the f32 baseline on this workload.
    key = jax.random.PRNGKey(11)
    xy, labels = spirals(100, jax.random.PRNGKey(13))
    x_all = model.embed(xy)
    y_all = jax.nn.one_hot(labels, model.CLASSES)

    finals = {}
    for quantized in [False, True]:
        params = model.init_params(key)
        p = [params[k] for k in ["w1", "b1", "w2", "b2", "w3", "b3"]]
        step = jax.jit(model.make_train_step(quantized=quantized, lr=0.1))
        rng = np.random.default_rng(1)
        loss = None
        for _ in range(60):
            idx = rng.choice(len(x_all), model.BATCH, replace=False)
            out = step(*p, x_all[idx], y_all[idx])
            p = list(out[:-1])
            loss = float(out[-1])
        finals[quantized] = loss
    assert finals[True] < finals[False] + 0.35, f"HFP8 {finals[True]} vs fp32 {finals[False]}"


def test_artifacts_lower_to_hlo_text():
    arts = artifacts()
    assert set(arts) == {"train_step_hfp8", "train_step_fp32", "predict_hfp8", "gemm_fp8_fp16"}
    for name, lowered in arts.items():
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name
