"""Make `compile.*` importable whether pytest runs from `python/` or the
repository root (the CI gate does the latter), and keep the suite
collectable when `hypothesis` is absent from the offline image (the
property tests skip; the example-based tests still run)."""

import os
import sys
import types

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _strategy(*_args, **_kwargs):
        return None

    for _name in ("floats", "integers", "sampled_from", "booleans", "just", "tuples", "lists"):
        setattr(_st, _name, _strategy)

    def _given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed in the offline image")

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
