"""The CORE correctness signal: the L1 Pallas ExSdotp kernel must match
the pure-jnp oracle bit for bit, across shapes, formats and block
configurations (hypothesis-driven)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    FP8,
    FP8ALT,
    FP16,
    FP16ALT,
    exsdotp_gemm,
    exsdotp_gemm_ref,
    gemm_f32_ref,
)

FORMAT_PAIRS = [(FP8, FP16), (FP8ALT, FP16), (FP16, FP16ALT), (FP8, FP16ALT)]


def rand(m, n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((m, n)) * scale).astype(np.float32)


@pytest.mark.parametrize("src,dst", FORMAT_PAIRS, ids=lambda f: getattr(f, "name", str(f)))
def test_kernel_matches_ref_bitwise(src, dst):
    a = rand(16, 24, 1)
    b = rand(24, 20, 2)
    ref = np.asarray(exsdotp_gemm_ref(a, b, src, dst))
    ker = np.asarray(exsdotp_gemm(a, b, src=src, dst=dst, block_m=8, block_n=8))
    np.testing.assert_array_equal(ker, ref)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 24),
    n=st.integers(1, 24),
    kp=st.integers(1, 12),
    bm=st.sampled_from([4, 8, 16]),
    bn=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis_shapes(m, n, kp, bm, bn, seed):
    k = 2 * kp
    a = rand(m, k, seed)
    b = rand(k, n, seed + 1)
    ref = np.asarray(exsdotp_gemm_ref(a, b, FP8, FP16))
    ker = np.asarray(exsdotp_gemm(a, b, src=FP8, dst=FP16, block_m=bm, block_n=bn))
    np.testing.assert_array_equal(ker, ref)


def test_block_shape_does_not_change_numerics():
    a = rand(32, 32, 7)
    b = rand(32, 32, 8)
    outs = [
        np.asarray(exsdotp_gemm(a, b, src=FP8ALT, dst=FP16, block_m=bm, block_n=bn))
        for bm, bn in [(8, 8), (16, 32), (32, 16), (32, 32)]
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_kernel_approximates_f32_gemm():
    a = rand(16, 32, 3, scale=0.3)
    b = rand(32, 16, 4, scale=0.3)
    gold = np.asarray(gemm_f32_ref(a, b))
    ker = np.asarray(exsdotp_gemm(a, b, src=FP8ALT, dst=FP16))
    rel = np.abs(ker - gold) / np.maximum(np.abs(gold), 1.0)
    assert rel.max() < 0.25, f"relative error {rel.max()}"


def test_expanding_accumulation_beats_narrow_accumulation():
    # The point of ExSdotp: accumulating FP8 products in FP16 loses far
    # less than accumulating in FP8. Emulate the narrow variant with the
    # ref oracle and dst = src.
    a = rand(8, 128, 5, scale=0.5)
    b = rand(128, 8, 6, scale=0.5)
    gold = np.asarray(gemm_f32_ref(np.asarray(jnp.asarray(a)), b))
    wide = np.asarray(exsdotp_gemm_ref(a, b, FP8, FP16))
    narrow = np.asarray(exsdotp_gemm_ref(a, b, FP8, FP8))
    err_wide = np.abs(wide - gold).mean()
    err_narrow = np.abs(narrow - gold).mean()
    assert err_wide < err_narrow, f"wide {err_wide} vs narrow {err_narrow}"


def test_nan_and_inf_propagate():
    a = rand(4, 4, 9)
    b = rand(4, 4, 10)
    a[0, 0] = np.nan
    out = np.asarray(exsdotp_gemm(a, b, src=FP8, dst=FP16))
    assert np.isnan(out[0]).all()
    assert np.isfinite(out[1:]).all()
