"""Quantizer validation against ml_dtypes (an independent, battle-tested
minifloat implementation) plus algebraic properties via hypothesis."""

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import FP8, FP8ALT, FP16, FP16ALT, FP32, quantize

# (our format, the equivalent ml_dtypes dtype)
PAIRS = [
    (FP8, ml_dtypes.float8_e5m2),
    (FP8ALT, ml_dtypes.float8_e4m3),  # IEEE e4m3 (with inf) == paper's FP8alt
    (FP16, np.float16),
    (FP16ALT, ml_dtypes.bfloat16),
]


@pytest.mark.parametrize("fmt,dtype", PAIRS, ids=[f.name for f, _ in PAIRS])
def test_quantize_matches_ml_dtypes_on_random_values(fmt, dtype):
    rng = np.random.default_rng(42)
    x = np.concatenate(
        [
            rng.standard_normal(512).astype(np.float32),
            rng.standard_normal(512).astype(np.float32) * 1e4,
            rng.standard_normal(512).astype(np.float32) * 1e-4,
            rng.standard_normal(256).astype(np.float32) * 2.0 ** rng.integers(-30, 30, 256),
        ]
    ).astype(np.float32)
    ours = np.asarray(quantize(jnp.asarray(x), fmt))
    theirs = x.astype(dtype).astype(np.float32)
    np.testing.assert_array_equal(ours, theirs)


@pytest.mark.parametrize("fmt,dtype", PAIRS, ids=[f.name for f, _ in PAIRS])
def test_quantize_exhaustive_8bit_grid(fmt, dtype):
    # Every representable value must be a fixed point of the quantizer.
    if np.dtype(dtype).itemsize > 1:
        pytest.skip("exhaustive only for 8-bit formats")
    all_bits = np.arange(256, dtype=np.uint8).view(dtype)
    finite = all_bits[np.isfinite(all_bits.astype(np.float32))].astype(np.float32)
    q = np.asarray(quantize(jnp.asarray(finite), fmt))
    np.testing.assert_array_equal(q, finite)


@settings(max_examples=300, deadline=None)
@given(
    st.floats(min_value=-(2.0**98), max_value=2.0**98, allow_nan=False, width=32),
    st.sampled_from([FP8, FP8ALT, FP16, FP16ALT]),
)
def test_quantize_idempotent(x, fmt):
    x32 = jnp.float32(x)
    once = quantize(x32, fmt)
    twice = quantize(once, fmt)
    assert (once == twice) | (jnp.isnan(once) & jnp.isnan(twice))


@settings(max_examples=200, deadline=None)
@given(
    st.floats(min_value=-240.0, max_value=240.0, allow_nan=False, width=32),
    st.floats(min_value=-240.0, max_value=240.0, allow_nan=False, width=32),
)
def test_quantize_monotone_fp8alt(a, b):
    qa = float(quantize(jnp.float32(a), FP8ALT))
    qb = float(quantize(jnp.float32(b), FP8ALT))
    if a <= b:
        assert qa <= qb


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=2.0**-26, max_value=2.0**13, allow_nan=False, width=32))
def test_quantize_relative_error_bound(x):
    # |q - x| <= ulp/2 <= x * 2^-man_bits / 2 for normal x.
    for fmt in [FP8, FP8ALT, FP16]:
        if x < 2.0**fmt.emin or x > fmt.max_finite:
            continue
        q = float(quantize(jnp.float32(x), fmt))
        rel = abs(q - np.float32(x)) / np.float32(x)
        assert rel <= 2.0 ** (-fmt.man_bits - 1) * 1.0000001


def test_specials():
    x = jnp.asarray([np.inf, -np.inf, np.nan, 0.0, -0.0], jnp.float32)
    for fmt in [FP8, FP8ALT, FP16, FP16ALT, FP32]:
        q = np.asarray(quantize(x, fmt))
        assert q[0] == np.inf and q[1] == -np.inf
        assert np.isnan(q[2])
        assert q[3] == 0.0 and not np.signbit(q[3])
        assert q[4] == 0.0 and np.signbit(q[4])


def test_overflow_to_inf_and_saturation_boundary():
    # FP8 max finite = 57344; halfway to the next grid point overflows.
    assert float(quantize(jnp.float32(57344.0), FP8)) == 57344.0
    assert float(quantize(jnp.float32(70000.0), FP8)) == np.inf
    # FP8alt max finite = 240.
    assert float(quantize(jnp.float32(240.0), FP8ALT)) == 240.0
    assert float(quantize(jnp.float32(260.0), FP8ALT)) == np.inf


def test_subnormal_grid():
    # FP16 min subnormal = 2^-24; half of it rounds to 0 (RNE tie→even).
    tiny = np.float32(2.0**-24)
    assert float(quantize(jnp.float32(tiny), FP16)) == tiny
    assert float(quantize(jnp.float32(tiny / 2), FP16)) == 0.0
    assert float(quantize(jnp.float32(tiny * 0.75), FP16)) == tiny
