"""L2: the JAX training model -- an MLP classifier trained with
HFP8-style mixed-precision GEMMs (Sun et al. [7], the paper's motivating
NN-training workload).

Scheme:
  * forward matmuls  : FP8alt (e4m3) operands -> FP16 accumulation
  * backward matmuls : FP8 (e5m2) operands -> FP16 accumulation
  * master weights, bias, optimizer: f32

Every matmul runs through the L1 Pallas ExSdotp kernel, so the whole
training step lowers to one HLO module that the Rust runtime executes
via PJRT -- Python never touches the request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import FP8, FP8ALT, FP16, exsdotp_gemm

# Compiled-in problem shape (the AOT artifact is shape-specialized).
BATCH = 64
FEATURES = 4  # spiral (x, y, r^2, 1) embedding
HIDDEN = 32
CLASSES = 4  # 3 spiral arms + 1 padding class (even K for ExSdotp pairs)

#: (fwd_src, fwd_dst, bwd_src, bwd_dst)
HFP8 = (FP8ALT, FP16, FP8, FP16)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def qmatmul(x, w, cfg):
    """Quantized matmul: ExSdotp GEMM forward, ExSdotp GEMM backward."""
    return exsdotp_gemm(x, w, src=cfg[0], dst=cfg[1])


def _qmatmul_fwd(x, w, cfg):
    return qmatmul(x, w, cfg), (x, w)


def _qmatmul_bwd(cfg, res, g):
    x, w = res
    bwd_src, bwd_dst = cfg[2], cfg[3]
    dx = exsdotp_gemm(g, w.T, src=bwd_src, dst=bwd_dst)
    dw = exsdotp_gemm(x.T, g, src=bwd_src, dst=bwd_dst)
    return dx, dw


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def init_params(key):
    """He-initialized 3-layer MLP parameters (f32 master copies)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda fan_in: (2.0 / fan_in) ** 0.5
    return {
        "w1": jax.random.normal(k1, (FEATURES, HIDDEN), jnp.float32) * s(FEATURES),
        "b1": jnp.zeros((HIDDEN,), jnp.float32),
        "w2": jax.random.normal(k2, (HIDDEN, HIDDEN), jnp.float32) * s(HIDDEN),
        "b2": jnp.zeros((HIDDEN,), jnp.float32),
        "w3": jax.random.normal(k3, (HIDDEN, CLASSES), jnp.float32) * s(HIDDEN),
        "b3": jnp.zeros((CLASSES,), jnp.float32),
    }


def forward(params, x, quantized=True):
    """Logits for a batch. ``quantized`` selects HFP8 vs plain f32."""
    mm = (lambda a, b: qmatmul(a, b, HFP8)) if quantized else (lambda a, b: a @ b)
    h = jax.nn.relu(mm(x, params["w1"]) + params["b1"])
    h = jax.nn.relu(mm(h, params["w2"]) + params["b2"])
    return mm(h, params["w3"]) + params["b3"]


def loss_fn(params, x, y_onehot, quantized=True):
    """Softmax cross-entropy (f32)."""
    logits = forward(params, x, quantized)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def make_train_step(quantized=True, lr=0.05):
    """SGD training step: (params..., x, y) -> (params'..., loss)."""

    def step(w1, b1, w2, b2, w3, b3, x, y_onehot):
        params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "w3": w3, "b3": b3}
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, x, y_onehot, quantized))(params)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return (new["w1"], new["b1"], new["w2"], new["b2"], new["w3"], new["b3"], loss)

    return step


def predict(w1, b1, w2, b2, w3, b3, x):
    """Class logits, HFP8 forward pass (the serving-path artifact)."""
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "w3": w3, "b3": b3}
    return forward(params, x, quantized=True)


def embed(xy):
    """Embed raw 2-D spiral coordinates into the FEATURES-dim input."""
    x, y = xy[..., 0], xy[..., 1]
    return jnp.stack([x, y, x * x + y * y, jnp.ones_like(x)], axis=-1)
