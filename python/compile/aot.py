"""Lower the L2 model (+ standalone L1 kernels) to HLO text artifacts.

HLO *text* is the interchange format: jax >= 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 (the `xla` crate's
backend) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Run once at build time (`make artifacts`); the Rust binary then loads
and executes the artifacts via PJRT with no Python anywhere near the
request path.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import FP8, FP16, exsdotp_gemm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((model.FEATURES, model.HIDDEN), f32),  # w1
        jax.ShapeDtypeStruct((model.HIDDEN,), f32),  # b1
        jax.ShapeDtypeStruct((model.HIDDEN, model.HIDDEN), f32),  # w2
        jax.ShapeDtypeStruct((model.HIDDEN,), f32),  # b2
        jax.ShapeDtypeStruct((model.HIDDEN, model.CLASSES), f32),  # w3
        jax.ShapeDtypeStruct((model.CLASSES,), f32),  # b3
    )


def artifacts():
    f32 = jnp.float32
    batch_x = jax.ShapeDtypeStruct((model.BATCH, model.FEATURES), f32)
    batch_y = jax.ShapeDtypeStruct((model.BATCH, model.CLASSES), f32)

    out = {}

    step_hfp8 = model.make_train_step(quantized=True)
    out["train_step_hfp8"] = jax.jit(step_hfp8).lower(*param_specs(), batch_x, batch_y)

    step_f32 = model.make_train_step(quantized=False)
    out["train_step_fp32"] = jax.jit(step_f32).lower(*param_specs(), batch_x, batch_y)

    predict = lambda *args: (model.predict(*args),)
    out["predict_hfp8"] = jax.jit(predict).lower(*param_specs(), batch_x)

    # Standalone L1 kernel artifact (quickstart + runtime tests).
    gm = jax.ShapeDtypeStruct((32, 32), f32)
    kern = lambda a, b: (exsdotp_gemm(a, b, src=FP8, dst=FP16),)
    out["gemm_fp8_fp16"] = jax.jit(kern).lower(gm, gm)

    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    for name, lowered in artifacts().items():
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    # Stamp for make's dependency tracking.
    with open(os.path.join(args.outdir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
