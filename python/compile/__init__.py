"""Build-time Python (L1 Pallas kernels + L2 JAX model + AOT lowering).

Never imported at runtime: the Rust coordinator executes the lowered
HLO artifacts through PJRT.
"""
