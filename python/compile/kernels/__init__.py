"""L1: Pallas kernels for the paper's compute hot-spot (build-time only)."""

from .exsdotp_gemm import exsdotp_gemm
from .quantize import FP8, FP8ALT, FP16, FP16ALT, FP32, FpFormat, quantize, quantize_ste
from .ref import exsdotp_gemm_ref, gemm_f32_ref
