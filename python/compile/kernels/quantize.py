"""Minifloat quantization in pure jnp (bitwise-correct RNE).

The paper's formats (§III-A) as (exp_bits, man_bits) pairs, all with
full IEEE-754 semantics — subnormals, ±inf, RNE — mirroring
``rust/src/formats``. Quantization maps an f32 tensor onto the minifloat
grid; it is the software emulation of storing a value in the narrow
format, exactly like the operand packing the MiniFloat-NN hardware does
in its register file.

The implementation is branch-free jnp (usable inside Pallas kernels and
under ``jax.jit``): the grid step for each element is ``2^(e - man_bits)``
with ``e = clamp(floor(log2 |x|), emin, ·)``, rounding is delegated to
the host's float rounding through a scaled ``jnp.round`` (ties-to-even),
and overflow saturates to ±inf per IEEE RNE.
"""

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class FpFormat:
    """A minifloat format descriptor (mirrors the Rust `FpFormat`)."""

    exp_bits: int
    man_bits: int
    name: str = ""

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def emax(self) -> int:
        return self.bias

    @property
    def max_finite(self) -> float:
        frac = 2.0 - 2.0 ** (-self.man_bits)
        return frac * 2.0**self.emax

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.emin - self.man_bits)


#: FP8 (e5m2) — FP16 dynamic range, 2-bit mantissa.
FP8 = FpFormat(5, 2, "FP8")
#: FP8alt (e4m3) — IEEE e4m3 (with inf), the HFP8 forward format.
FP8ALT = FpFormat(4, 3, "FP8alt")
#: IEEE binary16.
FP16 = FpFormat(5, 10, "FP16")
#: bfloat16 layout with IEEE semantics.
FP16ALT = FpFormat(8, 7, "FP16alt")
#: IEEE binary32 (identity quantization for f32 tensors).
FP32 = FpFormat(8, 23, "FP32")


def quantize(x, fmt: FpFormat):
    """Round ``x`` (f32) to the nearest ``fmt`` value (RNE), as f32.

    Exactly representable values pass through; overflow → ±inf;
    subnormal range uses the fixed grid ``2^(emin - man_bits)``; NaN
    passes through.
    """
    if fmt.man_bits >= 23 and fmt.exp_bits >= 8:
        return jnp.asarray(x, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    ax = jnp.abs(x)
    # Exponent of each element, clamped at emin (subnormal grid floor).
    # frexp: x = m * 2^e with m in [0.5, 1) → floor(log2|x|) = e - 1.
    _, e = jnp.frexp(jnp.where(ax == 0, 1.0, ax))
    e = jnp.maximum(e - 1, fmt.emin)
    # ldexp, not exp2: powers of two must be exact, and exp2 is a
    # (possibly 1-ulp-off) transcendental approximation on some backends.
    step = jnp.ldexp(jnp.float32(1.0), e - fmt.man_bits)
    q = jnp.round(x / step) * step
    # Rounding can carry to the next binade (e.g. 1.1111 → 10.000);
    # that result is still on the grid, so no fixup is needed there.
    # Overflow: values that round beyond max_finite become ±inf (the
    # IEEE RNE overflow rule: anything ≥ maxfinite + ulp/2 overflows).
    limit = fmt.max_finite * (1.0 + 2.0 ** (-fmt.man_bits - 1))
    q = jnp.where(ax >= limit, jnp.sign(x) * jnp.inf, q)
    # Zero and non-finite passthrough.
    q = jnp.where(jnp.isfinite(x), q, x)
    q = jnp.where(ax == 0, x, q)
    return q.astype(jnp.float32)


def quantize_ste(x, fmt: FpFormat):
    """Quantize with a straight-through gradient (for training)."""
    import jax

    zero = x - jax.lax.stop_gradient(x)
    return zero + jax.lax.stop_gradient(quantize(x, fmt))
