"""Pure-jnp correctness oracle for the ExSdotp GEMM kernel.

Models the MiniFloat-NN semantics at the level that matters for
training numerics: inputs quantized to the source format, products
computed exactly (an f32 holds any product of two <=FP16 values
exactly), and the accumulator rounded to the *destination* format once
per ExSdotp step -- i.e. once per pair of k-elements (eq. 1), matching
the hardware's single rounding per fused operation.
"""

import jax.numpy as jnp

from .quantize import FpFormat, quantize


def exsdotp_gemm_ref(a, b, src: FpFormat, dst: FpFormat):
    """C = A.B with ExSdotp numerics (slow reference, small shapes).

    ``a``: (M, K) f32, ``b``: (K, N) f32; K must be even. Returns (M, N)
    f32 holding dst-format values.
    """
    aq = quantize(a, src)
    bq = quantize(b, src)
    m, k = aq.shape
    _, n = bq.shape
    assert k % 2 == 0, "ExSdotp consumes k-pairs"
    acc = jnp.zeros((m, n), jnp.float32)
    for i in range(k // 2):
        # One fused op: two exact products + accumulator, single rounding
        # into the destination format.
        p = (
            aq[:, 2 * i : 2 * i + 1] * bq[2 * i : 2 * i + 1, :]
            + aq[:, 2 * i + 1 : 2 * i + 2] * bq[2 * i + 1 : 2 * i + 2, :]
        )
        acc = quantize(acc + p, dst)
    return acc


def gemm_f32_ref(a, b):
    """Plain f32 GEMM for loose comparisons."""
    return jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)
