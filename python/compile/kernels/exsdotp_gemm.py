"""L1 Pallas kernel: blocked GEMM with ExSdotp (expanding dot-product)
numerics.

Hardware-adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
SSR/FREP streaming of operand pairs from a scratchpad maps to Pallas
``BlockSpec``-driven HBM→VMEM tiling; the expanding accumulation
(narrow multiply, wide accumulate) maps to keeping the accumulator in
the destination format across the K loop while quantizing at
dot-product-pair granularity — the per-ExSdotp rounding of the fused
unit. ``interpret=True`` everywhere: the CPU PJRT client cannot run
Mosaic custom-calls (see /opt/xla-example/README.md), and correctness —
not TPU wall-clock — is what the AOT path needs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import FpFormat, quantize


def _kernel(a_ref, b_ref, o_ref, *, src: FpFormat, dst: FpFormat, k: int):
    """One (BM, BN) output tile: stream K in pairs, round per pair."""
    a = quantize(a_ref[...], src)  # (BM, K) source-format operands
    b = quantize(b_ref[...], src)  # (K, BN)

    def body(i, acc):
        # The fused op: two exact products + wide accumulator, one
        # rounding into dst (eq. 1). Slices are static-size (2 columns).
        a2 = jax.lax.dynamic_slice_in_dim(a, 2 * i, 2, axis=1)
        b2 = jax.lax.dynamic_slice_in_dim(b, 2 * i, 2, axis=0)
        prod = a2 @ b2  # (BM, BN): p0 + p1, exact in f32 for ≤FP16 sources
        return quantize(acc + prod, dst)

    acc0 = jnp.zeros(o_ref.shape, jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, k // 2, body, acc0)


@functools.partial(jax.jit, static_argnames=("src", "dst", "block_m", "block_n"))
def exsdotp_gemm(a, b, src: FpFormat = None, dst: FpFormat = None, block_m: int = 32, block_n: int = 32):
    """C = A·B with ExSdotp numerics as a Pallas kernel.

    ``a``: (M, K), ``b``: (K, N), f32 carrying narrower values (they are
    re-quantized to ``src`` inside the kernel — idempotent if already on
    the grid). K must be even. M/N need not divide the block sizes;
    Pallas masks the remainder tiles.
    """
    assert src is not None and dst is not None
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and k % 2 == 0
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, src=src, dst=dst, k=k),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
